"""Sharded serving plane: hash-ring stability, windowed reassembly,
router replay, pool liveness — and the cross-process kill → re-hash →
exactly-once replay path."""

import multiprocessing as mp
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Domain, EventExecutor
from repro.serving import (
    SERVE_REQ,
    SERVE_RES,
    EchoServer,
    FleetController,
    HashRing,
    ReplicaPool,
    ResRow,
    ResultsCollector,
    ShardRouter,
    iter_requests,
    pack_results,
)


@pytest.fixture()
def dom():
    d = Domain.create(arena_capacity=32 << 20)
    yield d
    d.close()


def echo_tokens(prompt, max_new, vocab=50021):
    """The EchoServer's deterministic stream (replay must reproduce it)."""
    base = int(np.asarray(prompt, np.int64).sum())
    return [(base + 131 * i + 7) % vocab for i in range(max_new)]


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------


def test_ring_lookup_deterministic_across_instances():
    a = HashRing(range(4))
    b = HashRing([3, 1, 0, 2])       # insertion order must not matter
    for rid in range(500):
        assert a.lookup(rid) == b.lookup(rid)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 8), seed=st.integers(0, 1000))
def test_ring_grow_moves_only_to_new_shard(k, seed):
    """K -> K+1: every key either keeps its shard or moves TO the new one,
    and only ~1/(K+1) of keys move (consistent hashing's contract)."""
    rids = [seed * 10_000 + i for i in range(600)]
    ring = HashRing(range(k))
    before = {r: ring.lookup(r) for r in rids}
    ring.add(k)                       # the new replica
    moved = 0
    for r in rids:
        after = ring.lookup(r)
        if after != before[r]:
            assert after == k         # moves land on the new shard only
            moved += 1
    assert moved / len(rids) <= 2.5 / (k + 1)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 8), victim=st.integers(0, 7))
def test_ring_shrink_moves_only_victims_keys(k, victim):
    victim %= k
    rids = list(range(400))
    ring = HashRing(range(k))
    before = {r: ring.lookup(r) for r in rids}
    ring.remove(victim)
    for r in rids:
        after = ring.lookup(r)
        if before[r] != victim:
            assert after == before[r]  # survivors' keys never move
        else:
            assert after != victim


def test_ring_candidates_distinct_and_primary_first():
    ring = HashRing(range(4))
    for rid in range(50):
        c = ring.candidates(rid, 3)
        assert len(c) == 3 and len(set(c)) == 3
        assert c[0] == ring.lookup(rid)


# ---------------------------------------------------------------------------
# collector reassembly (seq window, gaps, generations)
# ---------------------------------------------------------------------------


def row(rid, gen, seq, toks, eos=False):
    return ResRow(rid, gen, seq, np.asarray(toks, np.int32), eos)


def test_collector_reorders_within_window(dom):
    c = ResultsCollector(dom)
    try:
        c.ingest(row(7, 0, 2, [30]))
        c.ingest(row(7, 0, 0, [10]))
        assert c.gaps == 1            # seq 2 arrived while expecting 0
        c.ingest(row(7, 0, 3, [40], eos=True))
        c.ingest(row(7, 0, 1, [20]))  # fills the gap: drains the window
        assert dict(c.pop_completed()) == {7: [10, 20, 30, 40]}
        assert c.stats()["open_streams"] == 0
    finally:
        c.close()


def test_collector_drops_duplicates_and_late_chunks(dom):
    c = ResultsCollector(dom)
    try:
        c.ingest(row(1, 0, 0, [1]))
        c.ingest(row(1, 0, 0, [1]))   # dup of an in-order chunk
        c.ingest(row(1, 0, 2, [3]))
        c.ingest(row(1, 0, 2, [3]))   # dup inside the window
        c.ingest(row(1, 0, 1, [2]))
        c.ingest(row(1, 0, 3, [4], eos=True))
        c.ingest(row(1, 0, 1, [2]))   # after completion
        assert dict(c.pop_completed()) == {1: [1, 2, 3, 4]}
        assert c.duplicates == 3
        assert c.n_completed == 1     # completion fired exactly once
    finally:
        c.close()


def test_collector_generation_supersede(dom):
    done = []
    c = ResultsCollector(dom, on_complete=lambda rid, t: done.append((rid, t)))
    try:
        c.ingest(row(5, 0, 0, [1]))
        c.ingest(row(5, 0, 1, [2]))   # partial gen-0 stream...
        c.ingest(row(5, 1, 0, [10]))  # ...superseded by the replay
        c.ingest(row(5, 0, 2, [3]))   # stale generation: ignored
        c.ingest(row(5, 1, 1, [20], eos=True))
        assert c.superseded == 1 and c.stale_gen == 1
        assert done == [(5, [10, 20])]
        assert dict(c.pop_completed()) == {5: [10, 20]}
    finally:
        c.close()


def test_collector_shard_snapshot_over_messages(dom):
    """End-to-end message path: chunks arrive via a real SERVE_RES topic and
    the per-shard depth/latency snapshot reflects the publisher's report."""
    pub = dom.create_publisher(SERVE_RES, "serve/res", depth=8)
    c = ResultsCollector(dom, topic="serve/res")
    try:
        loan = pub.borrow_loaded_message()
        pack_results(loan, [row(3, 0, 0, [5, 6]),
                            row(3, 0, 1, [7], eos=True)],
                     shard=2, depth=11, stamp=time.monotonic())
        pub.publish(loan)
        deadline = time.monotonic() + 5
        while c.n_completed < 1 and time.monotonic() < deadline:
            c.pump(0.05)
        assert dict(c.pop_completed()) == {3: [5, 6, 7]}
        assert c.shard_depths() == {2: 11}
        st_ = c.shard_stats()[2]
        assert st_["chunks"] == 1 and st_["lat_p50"] is not None
        pub.reclaim()
    finally:
        c.close()


# ---------------------------------------------------------------------------
# router: hashing, replay, load-aware tie-breaking
# ---------------------------------------------------------------------------


def test_router_routes_match_ring_and_flush_delivers(dom):
    router = ShardRouter(dom, range(3), max_new=4)
    subs = {k: dom.create_subscription(SERVE_REQ, router.topic(k))
            for k in range(3)}
    rids = [router.submit([i, i + 1]) for i in range(12)]
    assert router.flush() == 12
    got = {}
    for k, sub in subs.items():
        for ptr in sub.take():
            for r in iter_requests(ptr):
                got[r.rid] = (k, r.gen)
            ptr.release()
    assert sorted(got) == sorted(rids)
    for rid, (k, gen) in got.items():
        assert k == router.ring.lookup(rid) and gen == 0
    router.close()


def test_router_remove_shard_replays_exactly_dead_rids(dom):
    router = ShardRouter(dom, range(3), max_new=4)
    subs = {k: dom.create_subscription(SERVE_REQ, router.topic(k))
            for k in range(3)}
    rids = [router.submit([i]) for i in range(30)]
    router.flush()
    for sub in subs.values():          # drain the first wave
        for ptr in sub.take():
            ptr.release()
    victim = 1
    dead_rids = {r for r in rids if router.inflight[r].shard == victim}
    survivors_rids = set(rids) - dead_rids
    replayed = set(router.remove_shard(victim))
    assert replayed == dead_rids       # exactly the dead shard's rids
    router.flush()
    regot = {}
    for k, sub in subs.items():
        for ptr in sub.take():
            for r in iter_requests(ptr):
                regot[r.rid] = (k, r.gen)
            ptr.release()
    assert set(regot) == dead_rids
    for rid, (k, gen) in regot.items():
        assert k != victim and gen == 1
        assert router.inflight[rid].shard == k
    for rid in survivors_rids:         # untouched by the re-hash
        assert router.inflight[rid].gen == 0
    router.close()


def test_router_load_aware_tie_break(dom):
    depths = {}
    router = ShardRouter(dom, range(2), load_aware=True, load_slack=2,
                         stats_fn=lambda: depths)
    rid = 12345
    primary, alt = router.ring.candidates(rid, 2)
    depths.update({primary: 0, alt: 0})
    assert router.route(rid) == primary
    depths[primary] = 10               # overloaded: hop to the candidate
    assert router.route(rid) == alt
    assert router.tie_breaks == 1
    router.close()


def test_router_complete_drops_replay_record(dom):
    router = ShardRouter(dom, range(2))
    rid = router.submit([1, 2, 3])
    router.flush()
    assert rid in router.inflight
    router.complete(rid)
    assert rid not in router.inflight
    assert router.replay(rid) is None  # nothing to replay after completion
    router.close()


# ---------------------------------------------------------------------------
# end-to-end (in-process echo replicas)
# ---------------------------------------------------------------------------


def test_serving_end_to_end_in_process(dom):
    from repro.serving.messages import pack_results as pack

    K, N, MAX_NEW = 2, 16, 5
    router = ShardRouter(dom, range(K), max_new=MAX_NEW)
    collector = ResultsCollector(
        dom, on_complete=lambda rid, t: router.complete(rid),
        on_progress=router.touch)
    ex = EventExecutor(name="serve-test")
    res_pub = dom.create_publisher(SERVE_RES, "serve/res", depth=32)
    for k in range(K):
        sub = dom.create_subscription(SERVE_REQ, router.topic(k))
        srv = EchoServer(slots=2)
        rows: list[ResRow] = []

        def mk(srv=srv, rows=rows, k=k):
            def sink(rid, gen, seq, toks, eos):
                rows.append(ResRow(int(rid), gen, seq,
                                   np.asarray(toks, np.int32), eos))

            def flush():
                if not rows:
                    return
                loan = res_pub.borrow_loaded_message()
                pack(loan, rows, shard=k, depth=0, stamp=time.monotonic())
                res_pub.publish_blocking(loan, timeout=10)
                rows.clear()

            return sink, flush

        srv.stream_sink, flush = mk()
        srv.attach_executor(ex, sub, max_new=MAX_NEW, round_period_s=0.001,
                            on_round_end=flush)
    collector.attach_executor(ex)

    rng = np.random.default_rng(3)
    prompts = {router.submit(p): p
               for p in [rng.integers(0, 999, 6) for _ in range(N)]}
    router.flush()
    ex.spin(until=lambda: collector.n_completed >= N, timeout=30)
    ex.shutdown()
    results = dict(collector.pop_completed())
    assert len(results) == N
    for rid, prompt in prompts.items():
        assert results[rid] == echo_tokens(prompt, MAX_NEW)
    assert collector.duplicates == 0 and not router.inflight
    router.close()
    collector.close()


# ---------------------------------------------------------------------------
# cross-process: kill a replica mid-run -> re-hash -> exactly-once replay
# ---------------------------------------------------------------------------


def test_killed_replica_rids_replayed_exactly_once():
    ctx = mp.get_context("spawn")
    assert ctx  # replicas spawn via ReplicaPool (same start method)
    dom = Domain.create(arena_capacity=32 << 20)
    K, N, MAX_NEW = 3, 24, 6
    pool = ReplicaPool(dom, range(K), model="echo", slots=2,
                       round_period_s=0.005)
    try:
        pool.wait_ready(60)
        router = ShardRouter(dom, range(K), max_new=MAX_NEW)
        completions: dict[int, int] = {}

        def on_complete(rid, toks):
            completions[rid] = completions.get(rid, 0) + 1
            router.complete(rid)

        # pool shards its results topics (serve/res/<k>): the collector
        # merges one subscription per shard
        collector = ResultsCollector(dom, shards=range(K),
                                     on_complete=on_complete,
                                     on_progress=router.touch)
        ex = EventExecutor(name="head")
        collector.attach_executor(ex)

        def janitor():
            for shard in pool.poll():
                router.remove_shard(shard)
            for rid in router.stalled(5.0):
                router.replay(rid)
            router.flush(timeout=5.0)

        ex.add_timer(0.1, janitor)
        rng = np.random.default_rng(7)
        prompts = {}
        for _ in range(N):
            p = rng.integers(0, 999, 10)
            prompts[router.submit(p)] = p
        router.flush()

        ex.spin(until=lambda: collector.n_completed >= N // 4, timeout=30)
        # kill the shard with the most in-flight rids: guarantees the
        # replay path actually fires
        per_shard: dict[int, int] = {}
        for rec in router.inflight.values():
            per_shard[rec.shard] = per_shard.get(rec.shard, 0) + 1
        victim = max(per_shard, key=per_shard.get)
        pool.kill(victim)
        ex.spin(until=lambda: collector.n_completed >= N, timeout=60)
        ex.shutdown()

        results = dict(collector.pop_completed())
        assert len(results) == N                      # no rid lost
        assert all(n == 1 for n in completions.values())  # exactly once
        assert router.replays > 0                     # the kill bit someone
        for rid, prompt in prompts.items():
            # deterministic echo: the replayed stream is bit-identical
            assert results[rid] == echo_tokens(prompt, MAX_NEW), rid
        assert victim not in router.ring
        assert not pool.is_alive(victim)
        router.close()
        collector.close()
    finally:
        pool.stop()
        dom.close()


# ---------------------------------------------------------------------------
# pool liveness: leases catch a wedged (alive but not consuming) replica
# ---------------------------------------------------------------------------


def test_lease_refresh_on_take_and_staleness(dom):
    reg = dom.registry
    t = reg.topic_index("lease-topic")
    s = reg.add_subscriber(t, 1)  # fake pid: lease API only
    ages = reg.lease_ages(t)
    assert s in ages and ages[s] < 1.0
    reg.topics[t]["sub_lease_ns"][s] = 0  # force epoch-old lease
    assert reg.lease_ages(t)[s] > 10.0
    reg.take(t, s)                         # lease refresh on take
    assert reg.lease_ages(t)[s] < 1.0
    reg.topics[t]["sub_lease_ns"][s] = 0
    reg.refresh_lease(t, s)                # the idle heartbeat path
    assert reg.lease_ages(t)[s] < 1.0


# ---------------------------------------------------------------------------
# admission control: shed / queue at the rid + byte budget
# ---------------------------------------------------------------------------


def test_router_admission_sheds_over_budget(dom):
    router = ShardRouter(dom, [0], max_new=4, max_inflight_rids=2)
    try:
        p = np.arange(8, dtype=np.int32)
        r1, r2 = router.submit(p), router.submit(p)
        assert r1 is not None and r2 is not None
        assert router.submit(p) is None           # budget hit: shed
        assert router.shed == 1 and router.shed_bytes == p.nbytes
        # pinned submissions (warmup / tests) bypass admission entirely,
        # though they do occupy budget once in flight
        pinned = router.submit(p, shard=0)
        assert pinned is not None
        router.complete(pinned)
        # a completion frees budget for the next submit
        router.complete(r1)
        assert router.submit(p) is not None
        assert router.shed == 1                   # no further sheds
        assert router.stats()["shed"] == 1
    finally:
        router.close()


def test_router_admission_byte_budget(dom):
    p = np.arange(8, dtype=np.int32)              # 32 bytes
    router = ShardRouter(dom, [0], prefix="adm/req", max_new=4,
                         max_inflight_bytes=p.nbytes + 8)
    try:
        assert router.submit(p) is not None
        assert router.submit(p) is None           # 64 > 40: shed
        assert router.shed == 1 and router.inflight_bytes == p.nbytes
    finally:
        router.close()


def test_router_admission_queue_drains_on_completion(dom):
    router = ShardRouter(dom, [0], max_new=4, max_inflight_rids=1,
                         admission="queue", queue_limit=2)
    try:
        p = np.arange(6, dtype=np.int32)
        r1 = router.submit(p)
        r2 = router.submit(p)                     # over budget: queued
        r3 = router.submit(p)                     # queued
        assert None not in (r1, r2, r3)
        assert router.submit(p) is None           # queue full: shed
        assert router.stats()["queued"] == 2 and router.queued_total == 2
        assert len(router.inflight) == 1
        with pytest.raises(ValueError):
            router.submit(p, rid=r2)              # queued rids are in flight
        router.complete(r1)                       # frees budget -> admits r2
        assert r2 in router.inflight and r3 not in router.inflight
        assert router.stats()["queued"] == 1
        router.complete(r2)
        assert r3 in router.inflight and router.stats()["queued"] == 0
    finally:
        router.close()


# ---------------------------------------------------------------------------
# regression: a flush-stall re-buffered row must not double-publish after
# the rid is replayed (the _pending double-buffering bug)
# ---------------------------------------------------------------------------


def test_flush_stall_rebuffer_then_replay_publishes_once(dom):
    router = ShardRouter(dom, [0], depth=1, max_new=4)
    sub = dom.create_subscription(SERVE_REQ, router.topic(0))
    try:
        p1, p2 = (np.arange(4, dtype=np.int32), np.arange(5, dtype=np.int32))
        rid1 = router.submit(p1)
        assert router.flush(timeout=5.0) == 1     # occupies the depth-1 ring
        held = sub.take_all()                     # take WITHOUT releasing:
        assert len(held) == 1                     # the slot stays pinned
        rid2 = router.submit(p2)
        assert router.flush(timeout=0.2) == 0     # slot pinned: stall
        assert router.flush_stalls == 1           # rid2's row parked in _pending
        # the stall-replay path fires while the row is parked: gen 0 row in
        # _pending is now superseded by the gen 1 replay row
        assert router.replay(rid2) == 0
        held[0].release()                         # free the ring slot
        assert router.flush(timeout=5.0) == 1     # ONE row ships, not two
        assert router.dropped_superseded == 1
        rows = []
        for ptr in sub.take_all():
            rows.extend(iter_requests(ptr))
            ptr.release()
        assert [(r.rid, r.gen) for r in rows] == [(rid2, 1)]
        assert router.inflight[rid1].gen == 0     # untouched bystander
    finally:
        sub.close()
        router.close()


# ---------------------------------------------------------------------------
# work stealing: cold rids only, generation gate keeps the race exactly-once
# ---------------------------------------------------------------------------


def test_steal_moves_only_cold_rids_and_gate_dedups(dom):
    MAX_NEW = 4
    router = ShardRouter(dom, [0, 1], max_new=MAX_NEW)
    sub0 = dom.create_subscription(SERVE_REQ, router.topic(0))
    sub1 = dom.create_subscription(SERVE_REQ, router.topic(1))
    completions: dict[int, int] = {}
    collector = ResultsCollector(
        dom, on_complete=lambda rid, t: completions.__setitem__(
            rid, completions.get(rid, 0) + 1))
    try:
        rng = np.random.default_rng(11)
        prompts = {}
        for _ in range(4):                        # all pinned to shard 0
            p = rng.integers(0, 999, 6)
            prompts[router.submit(p, shard=0)] = p
        router.flush(timeout=5.0)
        rids = sorted(prompts)
        hot = rids[0]
        router.touch(hot)                         # a chunk landed: not cold
        moved = router.steal(1, 0, limit=10)
        assert sorted(moved) == rids[1:]          # the hot rid stays put
        assert router.steals == 3
        assert router.inflight[hot].shard == 0
        assert router.inflight[hot].gen == 0
        for r in moved:
            assert router.inflight[r].shard == 1
            assert router.inflight[r].gen == 1
        router.flush(timeout=5.0)                 # ships the stolen rows

        # both replicas now decode the stolen rids (shard 0 holds the stale
        # gen-0 copies): the generation gate + collector supersede/dedup
        # must resolve the race to exactly one completion per rid
        def drain(sub, srv):
            rows = []
            srv.stream_sink = lambda rid, gen, seq, toks, eos: rows.append(
                ResRow(int(rid), gen, seq, np.asarray(toks, np.int32), eos))
            for ptr in sub.take_all():
                srv.ingest_serve_message(ptr)
                ptr.release()
            while not srv.idle:
                srv.step_rounds()
            return rows

        rows1 = drain(sub1, EchoServer(slots=4))  # the thief (gen 1)
        rows0 = drain(sub0, EchoServer(slots=4))  # the victim (gen 0, stale)
        assert {r.rid for r in rows1} == set(moved)
        assert {r.rid for r in rows0} == set(rids)
        for r in rows1:                           # thief wins the race
            collector.ingest(r)
        for r in rows0:                           # stale copies arrive late
            collector.ingest(r)
        assert completions == {r: 1 for r in rids}
        assert collector.stale_gen > 0 or collector.duplicates > 0
        for rid, p in prompts.items():
            assert collector.result(rid) == echo_tokens(p, MAX_NEW)
    finally:
        sub0.close()
        sub1.close()
        collector.close()
        router.close()


# ---------------------------------------------------------------------------
# regression: the pool's cached topic index must die with the topic's
# generation (layout v4 recycles topic slots)
# ---------------------------------------------------------------------------


def test_pool_lease_cache_invalidated_on_topic_recycle(dom):
    pool = ReplicaPool(dom, [])                   # no replicas: cache machinery
    reg = dom.registry
    try:
        t0 = reg.topic_index("serve/req/0")
        s0 = reg.add_subscriber(t0, 1)            # fake pid: lease API only
        assert not pool._lease_stale(0)           # fresh lease, cache primed
        assert pool._tidx[0] == (t0, reg.topic_gen(t0))
        reg.topics[t0]["sub_lease_ns"][s0] = 0    # epoch-old lease
        assert pool._lease_stale(0)               # wedged detection works
        # recycle the slot under the cache: destroy, re-create as ANOTHER
        # topic in the same row (gen bumps), give it an epoch-old lease —
        # the stale cached index would misread it as shard 0's wedged lease
        reg.destroy_topic("serve/req/0")
        assert reg.topic_index("unrelated/topic") == t0
        s1 = reg.add_subscriber(t0, 1)
        reg.topics[t0]["sub_lease_ns"][s1] = 0
        assert not pool._lease_stale(0)           # gen mismatch: not our topic
        assert 0 not in pool._tidx                # cache dropped, not re-primed
        # the next incarnation re-creates the shard topic in a fresh slot:
        # the poll must re-resolve and track the new (tidx, gen)
        t1 = reg.topic_index("serve/req/0")
        assert t1 != t0
        s2 = reg.add_subscriber(t1, 1)
        assert not pool._lease_stale(0)
        assert pool._tidx[0] == (t1, reg.topic_gen(t1))
        reg.topics[t1]["sub_lease_ns"][s2] = 0
        assert pool._lease_stale(0)
    finally:
        pool.stop()


# ---------------------------------------------------------------------------
# regression: wait_ready / kill key off the CURRENT incarnation after respawn
# ---------------------------------------------------------------------------


def test_pool_respawn_fresh_incarnation_wait_and_kill():
    dom = Domain.create(arena_capacity=32 << 20)
    pool = ReplicaPool(dom, [0], model="echo", slots=2, round_period_s=0.005)
    try:
        pool.wait_ready(60)
        assert pool.incarnation(0) == 0
        pid0 = pool._procs[0].pid
        pool.kill(0)
        assert pool.poll() == [0] and not pool.is_alive(0)
        pool.respawn(0)
        assert pool.incarnation(0) == 1
        # the dead predecessor's ready event was set long ago — wait_ready
        # must block on the FRESH incarnation's event, not return on the
        # stale one (the new replica needs real time to subscribe)
        pool.wait_ready(60, shards=[0])
        pid1 = pool._procs[0].pid
        assert pid1 != pid0 and pool.is_alive(0)
        assert pool.poll() == []                  # new incarnation is healthy
        # kill after respawn must target the NEW process, not the corpse
        pool.kill(0)
        assert not pool._procs[0].is_alive()
        assert pool.poll() == [0]
    finally:
        pool.stop()
        dom.close()


# ---------------------------------------------------------------------------
# cross-process elastic loop: kill -> respawn -> re-add -> exactly once
# ---------------------------------------------------------------------------


def test_controller_respawns_dead_replica_and_rejoins_exactly_once():
    dom = Domain.create(arena_capacity=32 << 20)
    K, N, MAX_NEW = 2, 16, 4
    pool = ReplicaPool(dom, range(K), model="echo", slots=2,
                       round_period_s=0.005)
    try:
        pool.wait_ready(60)
        router = ShardRouter(dom, range(K), max_new=MAX_NEW)
        completions: dict[int, int] = {}

        def on_complete(rid, toks):
            completions[rid] = completions.get(rid, 0) + 1
            router.complete(rid)

        collector = ResultsCollector(dom, shards=range(K),
                                     on_complete=on_complete,
                                     on_progress=router.touch)
        controller = FleetController(pool, router, collector,
                                     autoscale=False, respawn=True,
                                     respawn_backoff_s=0.0,
                                     stall_replay_s=5.0, flush_timeout_s=5.0)
        ex = EventExecutor(name="elastic-head")
        collector.attach_executor(ex)
        controller.attach_executor(ex, period_s=0.05)
        rng = np.random.default_rng(23)
        prompts = {}
        for _ in range(N):
            p = rng.integers(0, 999, 8)
            prompts[router.submit(p)] = p
        router.flush()
        ex.spin(until=lambda: collector.n_completed >= N // 4, timeout=30)
        per_shard: dict[int, int] = {}
        for rec in router.inflight.values():
            per_shard[rec.shard] = per_shard.get(rec.shard, 0) + 1
        victim = max(per_shard, key=per_shard.get)
        pool.kill(victim)
        ex.spin(until=lambda: collector.n_completed >= N, timeout=120)
        # load may drain before the respawn finishes joining: keep ticking
        ex.spin(until=lambda: (controller.respawns >= 1
                               and victim in router.ring), timeout=60)
        ex.shutdown()

        assert collector.n_completed >= N
        assert completions == {rid: 1 for rid in prompts}   # exactly once
        for rid, p in prompts.items():
            assert collector.result(rid) == echo_tokens(p, MAX_NEW) \
                or collector.result(rid) is None  # popped via on_complete only
        results = dict(collector.pop_completed())
        assert sorted(results) == sorted(prompts)
        for rid, p in prompts.items():
            assert results[rid] == echo_tokens(p, MAX_NEW), rid
        assert controller.deaths >= 1 and controller.respawns >= 1
        assert pool.is_alive(victim) and pool.incarnation(victim) >= 1
        assert victim in router.ring                        # healed fleet
        router.close()
        collector.close()
    finally:
        pool.stop()
        dom.close()
