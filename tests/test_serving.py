"""Sharded serving plane: hash-ring stability, windowed reassembly,
router replay, pool liveness — and the cross-process kill → re-hash →
exactly-once replay path."""

import multiprocessing as mp
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Domain, EventExecutor
from repro.serving import (
    SERVE_REQ,
    SERVE_RES,
    EchoServer,
    HashRing,
    ReplicaPool,
    ResRow,
    ResultsCollector,
    ShardRouter,
    iter_requests,
    pack_results,
)


@pytest.fixture()
def dom():
    d = Domain.create(arena_capacity=32 << 20)
    yield d
    d.close()


def echo_tokens(prompt, max_new, vocab=50021):
    """The EchoServer's deterministic stream (replay must reproduce it)."""
    base = int(np.asarray(prompt, np.int64).sum())
    return [(base + 131 * i + 7) % vocab for i in range(max_new)]


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------


def test_ring_lookup_deterministic_across_instances():
    a = HashRing(range(4))
    b = HashRing([3, 1, 0, 2])       # insertion order must not matter
    for rid in range(500):
        assert a.lookup(rid) == b.lookup(rid)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 8), seed=st.integers(0, 1000))
def test_ring_grow_moves_only_to_new_shard(k, seed):
    """K -> K+1: every key either keeps its shard or moves TO the new one,
    and only ~1/(K+1) of keys move (consistent hashing's contract)."""
    rids = [seed * 10_000 + i for i in range(600)]
    ring = HashRing(range(k))
    before = {r: ring.lookup(r) for r in rids}
    ring.add(k)                       # the new replica
    moved = 0
    for r in rids:
        after = ring.lookup(r)
        if after != before[r]:
            assert after == k         # moves land on the new shard only
            moved += 1
    assert moved / len(rids) <= 2.5 / (k + 1)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 8), victim=st.integers(0, 7))
def test_ring_shrink_moves_only_victims_keys(k, victim):
    victim %= k
    rids = list(range(400))
    ring = HashRing(range(k))
    before = {r: ring.lookup(r) for r in rids}
    ring.remove(victim)
    for r in rids:
        after = ring.lookup(r)
        if before[r] != victim:
            assert after == before[r]  # survivors' keys never move
        else:
            assert after != victim


def test_ring_candidates_distinct_and_primary_first():
    ring = HashRing(range(4))
    for rid in range(50):
        c = ring.candidates(rid, 3)
        assert len(c) == 3 and len(set(c)) == 3
        assert c[0] == ring.lookup(rid)


# ---------------------------------------------------------------------------
# collector reassembly (seq window, gaps, generations)
# ---------------------------------------------------------------------------


def row(rid, gen, seq, toks, eos=False):
    return ResRow(rid, gen, seq, np.asarray(toks, np.int32), eos)


def test_collector_reorders_within_window(dom):
    c = ResultsCollector(dom)
    try:
        c.ingest(row(7, 0, 2, [30]))
        c.ingest(row(7, 0, 0, [10]))
        assert c.gaps == 1            # seq 2 arrived while expecting 0
        c.ingest(row(7, 0, 3, [40], eos=True))
        c.ingest(row(7, 0, 1, [20]))  # fills the gap: drains the window
        assert dict(c.pop_completed()) == {7: [10, 20, 30, 40]}
        assert c.stats()["open_streams"] == 0
    finally:
        c.close()


def test_collector_drops_duplicates_and_late_chunks(dom):
    c = ResultsCollector(dom)
    try:
        c.ingest(row(1, 0, 0, [1]))
        c.ingest(row(1, 0, 0, [1]))   # dup of an in-order chunk
        c.ingest(row(1, 0, 2, [3]))
        c.ingest(row(1, 0, 2, [3]))   # dup inside the window
        c.ingest(row(1, 0, 1, [2]))
        c.ingest(row(1, 0, 3, [4], eos=True))
        c.ingest(row(1, 0, 1, [2]))   # after completion
        assert dict(c.pop_completed()) == {1: [1, 2, 3, 4]}
        assert c.duplicates == 3
        assert c.n_completed == 1     # completion fired exactly once
    finally:
        c.close()


def test_collector_generation_supersede(dom):
    done = []
    c = ResultsCollector(dom, on_complete=lambda rid, t: done.append((rid, t)))
    try:
        c.ingest(row(5, 0, 0, [1]))
        c.ingest(row(5, 0, 1, [2]))   # partial gen-0 stream...
        c.ingest(row(5, 1, 0, [10]))  # ...superseded by the replay
        c.ingest(row(5, 0, 2, [3]))   # stale generation: ignored
        c.ingest(row(5, 1, 1, [20], eos=True))
        assert c.superseded == 1 and c.stale_gen == 1
        assert done == [(5, [10, 20])]
        assert dict(c.pop_completed()) == {5: [10, 20]}
    finally:
        c.close()


def test_collector_shard_snapshot_over_messages(dom):
    """End-to-end message path: chunks arrive via a real SERVE_RES topic and
    the per-shard depth/latency snapshot reflects the publisher's report."""
    pub = dom.create_publisher(SERVE_RES, "serve/res", depth=8)
    c = ResultsCollector(dom, topic="serve/res")
    try:
        loan = pub.borrow_loaded_message()
        pack_results(loan, [row(3, 0, 0, [5, 6]),
                            row(3, 0, 1, [7], eos=True)],
                     shard=2, depth=11, stamp=time.monotonic())
        pub.publish(loan)
        deadline = time.monotonic() + 5
        while c.n_completed < 1 and time.monotonic() < deadline:
            c.pump(0.05)
        assert dict(c.pop_completed()) == {3: [5, 6, 7]}
        assert c.shard_depths() == {2: 11}
        st_ = c.shard_stats()[2]
        assert st_["chunks"] == 1 and st_["lat_p50"] is not None
        pub.reclaim()
    finally:
        c.close()


# ---------------------------------------------------------------------------
# router: hashing, replay, load-aware tie-breaking
# ---------------------------------------------------------------------------


def test_router_routes_match_ring_and_flush_delivers(dom):
    router = ShardRouter(dom, range(3), max_new=4)
    subs = {k: dom.create_subscription(SERVE_REQ, router.topic(k))
            for k in range(3)}
    rids = [router.submit([i, i + 1]) for i in range(12)]
    assert router.flush() == 12
    got = {}
    for k, sub in subs.items():
        for ptr in sub.take():
            for r in iter_requests(ptr):
                got[r.rid] = (k, r.gen)
            ptr.release()
    assert sorted(got) == sorted(rids)
    for rid, (k, gen) in got.items():
        assert k == router.ring.lookup(rid) and gen == 0
    router.close()


def test_router_remove_shard_replays_exactly_dead_rids(dom):
    router = ShardRouter(dom, range(3), max_new=4)
    subs = {k: dom.create_subscription(SERVE_REQ, router.topic(k))
            for k in range(3)}
    rids = [router.submit([i]) for i in range(30)]
    router.flush()
    for sub in subs.values():          # drain the first wave
        for ptr in sub.take():
            ptr.release()
    victim = 1
    dead_rids = {r for r in rids if router.inflight[r].shard == victim}
    survivors_rids = set(rids) - dead_rids
    replayed = set(router.remove_shard(victim))
    assert replayed == dead_rids       # exactly the dead shard's rids
    router.flush()
    regot = {}
    for k, sub in subs.items():
        for ptr in sub.take():
            for r in iter_requests(ptr):
                regot[r.rid] = (k, r.gen)
            ptr.release()
    assert set(regot) == dead_rids
    for rid, (k, gen) in regot.items():
        assert k != victim and gen == 1
        assert router.inflight[rid].shard == k
    for rid in survivors_rids:         # untouched by the re-hash
        assert router.inflight[rid].gen == 0
    router.close()


def test_router_load_aware_tie_break(dom):
    depths = {}
    router = ShardRouter(dom, range(2), load_aware=True, load_slack=2,
                         stats_fn=lambda: depths)
    rid = 12345
    primary, alt = router.ring.candidates(rid, 2)
    depths.update({primary: 0, alt: 0})
    assert router.route(rid) == primary
    depths[primary] = 10               # overloaded: hop to the candidate
    assert router.route(rid) == alt
    assert router.tie_breaks == 1
    router.close()


def test_router_complete_drops_replay_record(dom):
    router = ShardRouter(dom, range(2))
    rid = router.submit([1, 2, 3])
    router.flush()
    assert rid in router.inflight
    router.complete(rid)
    assert rid not in router.inflight
    assert router.replay(rid) is None  # nothing to replay after completion
    router.close()


# ---------------------------------------------------------------------------
# end-to-end (in-process echo replicas)
# ---------------------------------------------------------------------------


def test_serving_end_to_end_in_process(dom):
    from repro.serving.messages import pack_results as pack

    K, N, MAX_NEW = 2, 16, 5
    router = ShardRouter(dom, range(K), max_new=MAX_NEW)
    collector = ResultsCollector(
        dom, on_complete=lambda rid, t: router.complete(rid),
        on_progress=router.touch)
    ex = EventExecutor(name="serve-test")
    res_pub = dom.create_publisher(SERVE_RES, "serve/res", depth=32)
    for k in range(K):
        sub = dom.create_subscription(SERVE_REQ, router.topic(k))
        srv = EchoServer(slots=2)
        rows: list[ResRow] = []

        def mk(srv=srv, rows=rows, k=k):
            def sink(rid, gen, seq, toks, eos):
                rows.append(ResRow(int(rid), gen, seq,
                                   np.asarray(toks, np.int32), eos))

            def flush():
                if not rows:
                    return
                loan = res_pub.borrow_loaded_message()
                pack(loan, rows, shard=k, depth=0, stamp=time.monotonic())
                res_pub.publish_blocking(loan, timeout=10)
                rows.clear()

            return sink, flush

        srv.stream_sink, flush = mk()
        srv.attach_executor(ex, sub, max_new=MAX_NEW, round_period_s=0.001,
                            on_round_end=flush)
    collector.attach_executor(ex)

    rng = np.random.default_rng(3)
    prompts = {router.submit(p): p
               for p in [rng.integers(0, 999, 6) for _ in range(N)]}
    router.flush()
    ex.spin(until=lambda: collector.n_completed >= N, timeout=30)
    ex.shutdown()
    results = dict(collector.pop_completed())
    assert len(results) == N
    for rid, prompt in prompts.items():
        assert results[rid] == echo_tokens(prompt, MAX_NEW)
    assert collector.duplicates == 0 and not router.inflight
    router.close()
    collector.close()


# ---------------------------------------------------------------------------
# cross-process: kill a replica mid-run -> re-hash -> exactly-once replay
# ---------------------------------------------------------------------------


def test_killed_replica_rids_replayed_exactly_once():
    ctx = mp.get_context("spawn")
    assert ctx  # replicas spawn via ReplicaPool (same start method)
    dom = Domain.create(arena_capacity=32 << 20)
    K, N, MAX_NEW = 3, 24, 6
    pool = ReplicaPool(dom, range(K), model="echo", slots=2,
                       round_period_s=0.005)
    try:
        pool.wait_ready(60)
        router = ShardRouter(dom, range(K), max_new=MAX_NEW)
        completions: dict[int, int] = {}

        def on_complete(rid, toks):
            completions[rid] = completions.get(rid, 0) + 1
            router.complete(rid)

        # pool shards its results topics (serve/res/<k>): the collector
        # merges one subscription per shard
        collector = ResultsCollector(dom, shards=range(K),
                                     on_complete=on_complete,
                                     on_progress=router.touch)
        ex = EventExecutor(name="head")
        collector.attach_executor(ex)

        def janitor():
            for shard in pool.poll():
                router.remove_shard(shard)
            for rid in router.stalled(5.0):
                router.replay(rid)
            router.flush(timeout=5.0)

        ex.add_timer(0.1, janitor)
        rng = np.random.default_rng(7)
        prompts = {}
        for _ in range(N):
            p = rng.integers(0, 999, 10)
            prompts[router.submit(p)] = p
        router.flush()

        ex.spin(until=lambda: collector.n_completed >= N // 4, timeout=30)
        # kill the shard with the most in-flight rids: guarantees the
        # replay path actually fires
        per_shard: dict[int, int] = {}
        for rec in router.inflight.values():
            per_shard[rec.shard] = per_shard.get(rec.shard, 0) + 1
        victim = max(per_shard, key=per_shard.get)
        pool.kill(victim)
        ex.spin(until=lambda: collector.n_completed >= N, timeout=60)
        ex.shutdown()

        results = dict(collector.pop_completed())
        assert len(results) == N                      # no rid lost
        assert all(n == 1 for n in completions.values())  # exactly once
        assert router.replays > 0                     # the kill bit someone
        for rid, prompt in prompts.items():
            # deterministic echo: the replayed stream is bit-identical
            assert results[rid] == echo_tokens(prompt, MAX_NEW), rid
        assert victim not in router.ring
        assert not pool.is_alive(victim)
        router.close()
        collector.close()
    finally:
        pool.stop()
        dom.close()


# ---------------------------------------------------------------------------
# pool liveness: leases catch a wedged (alive but not consuming) replica
# ---------------------------------------------------------------------------


def test_lease_refresh_on_take_and_staleness(dom):
    reg = dom.registry
    t = reg.topic_index("lease-topic")
    s = reg.add_subscriber(t, 1)  # fake pid: lease API only
    ages = reg.lease_ages(t)
    assert s in ages and ages[s] < 1.0
    reg.topics[t]["sub_lease_ns"][s] = 0  # force epoch-old lease
    assert reg.lease_ages(t)[s] > 10.0
    reg.take(t, s)                         # lease refresh on take
    assert reg.lease_ages(t)[s] < 1.0
    reg.topics[t]["sub_lease_ns"][s] = 0
    reg.refresh_lease(t, s)                # the idle heartbeat path
    assert reg.lease_ages(t)[s] < 1.0
