"""End-to-end behaviour of the paper's system (the §V narrative, small).

Each test is one paper claim exercised through the public API:

* zero-copy latency is ~constant in payload size while the serialized
  path grows (Fig. 9, in-process variant — the multiprocess variant lives
  in benchmarks/fig9_latency.py);
* the bridge relays both directions without loops (Fig. 8 / §IV-D);
* the LiDAR chain improves when ONE edge is converted (Fig. 13, tiny);
* a publisher crash never corrupts the metadata plane (kernel-module
  guarantee, §IV-B).
"""

import time

import numpy as np
import pytest

from repro.core import (
    POINT_CLOUD2,
    Bridge,
    Bus,
    BusClient,
    Domain,
    deserialize,
    serialize,
)


def _pub_take_once(dom, pub, sub, nbytes):
    msg = pub.borrow_loaded_message()
    msg.data.extend(np.zeros(nbytes, np.uint8))
    t0 = time.perf_counter()
    pub.publish(msg)
    ptrs = sub.take()
    _ = ptrs[0].msg.data[:16].sum()
    dt = time.perf_counter() - t0
    ptrs[0].release()
    pub.reclaim()
    return dt


def test_zero_copy_latency_size_independent():
    with Domain.create(arena_capacity=256 << 20) as dom:
        pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
        sub = dom.create_subscription(POINT_CLOUD2, "t")
        small = [_pub_take_once(dom, pub, sub, 1 << 10) for _ in range(30)]
        large = [_pub_take_once(dom, pub, sub, 4 << 20) for _ in range(30)]
        # 4000x the bytes must NOT cost 4000x the time; allow generous jitter
        assert np.median(large) < 20 * np.median(small)

        # serialized path for contrast: scales with size
        m = POINT_CLOUD2.plain()
        m.data = np.zeros(1 << 10, np.uint8)
        t0 = time.perf_counter(); deserialize(serialize(m)); ts = time.perf_counter() - t0
        m.data = np.zeros(4 << 20, np.uint8)
        t0 = time.perf_counter(); deserialize(serialize(m)); tl = time.perf_counter() - t0
        assert tl > 10 * ts


def test_bridge_relays_and_prevents_loops():
    bus = Bus().start()
    try:
        with Domain.create(arena_capacity=32 << 20) as dom:
            br = Bridge(dom, bus.path, POINT_CLOUD2, "topic")
            agno_pub = dom.create_publisher(POINT_CLOUD2, "topic", depth=8)
            agno_sub = dom.create_subscription(POINT_CLOUD2, "topic")
            bus_cli = BusClient(bus.path)
            bus_cli.subscribe("topic")
            time.sleep(0.2)  # SUB frame lands (subscribe is fire-and-forget:
            # publishing before the bus registers it silently drops the fanout)

            # agnocast -> bus
            msg = agno_pub.borrow_loaded_message()
            msg.data.extend(np.arange(100, dtype=np.uint8))
            msg.set("stamp", 1.0)
            agno_pub.publish(msg)
            assert br.spin_once(timeout=1.0) >= 1
            got = bus_cli.recv(timeout=5.0)
            assert got is not None
            fields = deserialize(got[2])
            assert np.array_equal(fields["data"], np.arange(100, dtype=np.uint8))

            # bus -> agnocast
            m = POINT_CLOUD2.plain()
            m.data = np.arange(50, dtype=np.uint8)
            m.stamp = 2.0
            bus_cli.publish("topic", serialize(m))
            for _ in range(20):
                if br.spin_once(timeout=0.2):
                    break
            ptrs = agno_sub.take()
            # drain agnocast sub: it sees the original publish AND the relayed
            # one; the relayed one has bridge origin
            datas = sorted(len(p.msg.data) for p in ptrs)
            assert 50 in datas
            for p in ptrs:
                p.release()

            # loop prevention: bridge never re-relays its own messages
            before_out, before_in = br.relayed_out, br.relayed_in
            assert br.spin_once(timeout=0.3) == 0
            assert (br.relayed_out, br.relayed_in) == (before_out, before_in)
            br.close()
            bus_cli.close()
    finally:
        bus.stop()


@pytest.mark.slow
def test_pointcloud_chain_one_edge_conversion():
    from repro.apps import LidarSpec, run_chain

    lidars = (LidarSpec("top", 60_000, 0.05), LidarSpec("left", 1_000, 0.05),
              LidarSpec("right", 1_000, 0.05))
    base = run_chain(frames=8, agnocast_edges=frozenset(), lidars=lidars,
                     arena_mb=64)
    agno = run_chain(frames=8, agnocast_edges=frozenset({"top"}),
                     lidars=lidars, arena_mb=64)
    # >= 6 of 8: on a single timeshared core a heavily-loaded run may drop
    # trailing frames at the deadline; the chain property still holds.
    assert len(base.response_times) >= 6
    assert len(agno.response_times) >= 6
    assert all(t > 0 for t in base.response_times + agno.response_times)
    # merged clouds contain all three lidars' (filtered) points
    assert min(base.merged_points) > 60_000 * 0.5


def test_publisher_crash_leaves_plane_consistent():
    """Janitor (kernel exit-hook analogue): a dead publisher's entries are
    swept; the subscriber keeps working with other publishers."""
    import multiprocessing as mp

    from tests._mp_helpers import crash_publisher

    with Domain.create(arena_capacity=8 << 20) as dom:
        sub = dom.create_subscription(POINT_CLOUD2, "t")
        ctx = mp.get_context("spawn")
        proc = ctx.Process(target=crash_publisher, args=(dom.name,))
        proc.start()
        proc.join(timeout=30)
        dom.sweep()                      # the janitor runs
        # plane still serves a healthy publisher
        pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
        msg = pub.borrow_loaded_message()
        msg.data.extend(np.arange(10, dtype=np.uint8))
        pub.publish(msg)
        ptrs = sub.take()
        assert any(len(p.msg.data) == 10 for p in ptrs)
        for p in ptrs:
            p.release()
