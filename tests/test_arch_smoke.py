"""Per-architecture smoke tests: one forward/train step on a REDUCED config
of the same family; shapes + finiteness asserted. The FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model, WORKLOADS


def _smoke_batch(cfg, rng, batch=2, seq=12):
    ks = jax.random.split(rng, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "whisper":
        b["frames"] = jax.random.normal(ks[1], (batch, cfg.encoder_positions,
                                                 cfg.d_model), cfg.cdt)
    if cfg.family == "mllama":
        b["vision"] = jax.random.normal(ks[2], (batch, cfg.vision_tokens,
                                                cfg.d_model), cfg.cdt)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = model.forward(params, batch)
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jax.tree.reduce(
        lambda a, l: a + jnp.sum(jnp.square(l.astype(jnp.float32))), grads, 0.0)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, cache = model.prefill(params, batch, max_seq=16)
    assert logits.shape == (2, 1, cfg.vocab_size)
    shapes_in = jax.tree.map(lambda l: l.shape, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    logits2, cache = model.decode_step(params, cache, tok)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    # the decode cache must be shape-stable (guards cache-contract drift:
    # a step that returns per-token slices instead of the cache would pass
    # a single-step logits check but break the serving loop)
    assert jax.tree.map(lambda l: l.shape, cache) == shapes_in
    logits3, cache = model.decode_step(params, cache, tok)
    assert bool(jnp.isfinite(logits3).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_exact_assignment(arch):
    """Pin the assigned architecture table (guards accidental edits)."""
    cfg = get_config(arch)
    table = {
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151_936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151_936),
        "qwen3-8b": (36, 4096, 32, 8, 12_288, 151_936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151_936),
        "gemma-2b": (18, 2048, 8, 1, 16_384, 256_000),
        "llama3-8b": (32, 4096, 32, 8, 14_336, 128_256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
        "whisper-small": (12, 768, 12, 12, 3072, 51_865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28_672, 128_256),
        "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v
    # family extras
    if arch == "qwen2-moe-a2.7b":
        assert cfg.num_experts == 60 and cfg.top_k == 4 and cfg.num_shared_experts == 4
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.num_experts == 128 and cfg.top_k == 8
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64
    if arch == "gemma-2b":
        assert cfg.head_dim == 256 and cfg.mlp_act == "geglu"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_workloads(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    for wl in WORKLOADS.values():
        ok, why = model.supports(wl)
        if not ok:
            assert wl.name == "long_500k" and cfg.family not in ("xlstm", "zamba2")
            continue
        specs = model.input_specs(wl)
        assert "tokens" in specs
        if wl.kind == "decode":
            assert "cache" in specs
