"""agnolint: lint rules (violating + clean fixture per rule), layout
drift detection (the v5->v6 magic-bump rule), the bounded interleaving
checker (clean pass + non-vacuity via injected bugs), and regression
tests for the protocol bugs this PR's audit/model run surfaced."""

import json
import os
import shutil
import subprocess
import sys

import pytest

import repro.analysis.model as model
from repro.analysis import check_layout, lint_paths, lint_source
from repro.analysis.layout import extract_layout, write_lock
from repro.core import Registry
from repro.core.registry import _J_PENDING

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _rules(report):
    return sorted({f.rule for f in report.findings})


# ---------------------------------------------------------------------------
# AGNO-LOCK-001: shm stores need a write-locked context
# ---------------------------------------------------------------------------

_LOCK1_BAD = """
import numpy as np

class Thing:
    def __init__(self, shm):
        self.rows = np.frombuffer(shm.buf, dtype="u8")

    def mutate(self, i):
        self.rows[i] = 7
"""

_LOCK1_GOOD = """
import numpy as np

class Thing:
    def __init__(self, shm):
        self.rows = np.frombuffer(shm.buf, dtype="u8")

    def mutate(self, i):
        with self._locked(i):
            self.rows[i] = 7
"""


def test_lock001_unlocked_store_flagged():
    rep = lint_source(_LOCK1_BAD, "repro/core/fake.py")
    assert _rules(rep) == ["AGNO-LOCK-001"]


def test_lock001_locked_store_clean():
    rep = lint_source(_LOCK1_GOOD, "repro/core/fake.py")
    assert rep.findings == []


def test_lock001_readonly_lock_gives_no_license():
    src = _LOCK1_GOOD.replace("self._locked(i)",
                              "self._locked(i, write=False)")
    rep = lint_source(src, "repro/core/fake.py")
    assert _rules(rep) == ["AGNO-LOCK-001"]


# ---------------------------------------------------------------------------
# AGNO-LOCK-002: domain -> topic order, never topic -> domain or nested topic
# ---------------------------------------------------------------------------

_LOCK2_BAD = """
class Thing:
    def wrong(self, t):
        with self._topic_flock(t):
            with self._lock:
                pass
"""

_LOCK2_GOOD = """
class Thing:
    def right(self, t):
        with self._lock:
            with self._topic_flock(t):
                pass
"""


def test_lock002_order():
    assert _rules(lint_source(_LOCK2_BAD,
                              "repro/core/fake.py")) == ["AGNO-LOCK-002"]
    assert lint_source(_LOCK2_GOOD, "repro/core/fake.py").findings == []


# ---------------------------------------------------------------------------
# AGNO-LOCK-003: no blocking calls while any lock is held
# ---------------------------------------------------------------------------

_LOCK3_BAD = """
import time

class Thing:
    def slow(self, t):
        with self._topic_flock(t):
            time.sleep(0.1)
"""


def test_lock003_blocking_under_lock():
    assert _rules(lint_source(_LOCK3_BAD,
                              "repro/core/fake.py")) == ["AGNO-LOCK-003"]
    ok = _LOCK3_BAD.replace("            time.sleep(0.1)",
                            "            pass\n        time.sleep(0.1)")
    assert lint_source(ok, "repro/core/fake.py").findings == []


# ---------------------------------------------------------------------------
# AGNO-HOT-001/002: publish-hot-path purity (subsumes the old grep test)
# ---------------------------------------------------------------------------

def test_hot001_sleep_on_hot_path_module():
    src = "import time\n\ndef f():\n    time.sleep(1)\n"
    assert _rules(lint_source(src,
                              "repro/core/topic.py")) == ["AGNO-HOT-001"]
    # same code on a non-hot-path module is fine
    assert lint_source(src, "repro/apps/replay.py").findings == []


def test_hot002_queuefull_coupling_in_apps():
    src = "def f(e):\n    return isinstance(e, AgnocastQueueFull)\n"
    assert _rules(lint_source(src,
                              "repro/data/pipeline.py")) == ["AGNO-HOT-002"]
    assert lint_source(src, "repro/core/fake.py").findings == []


# ---------------------------------------------------------------------------
# AGNO-HOT-003: trace emit bodies stay allocation/lock/syscall-free
# ---------------------------------------------------------------------------

_HOT3_BAD = """
class TraceRing:
    def emit(self, stage, seq):
        data = {"stage": stage}
        self._pack(seq, self._mono())
"""


def test_hot003_emit_purity():
    rep = lint_source(_HOT3_BAD, "repro/obs/trace.py")
    assert _rules(rep) == ["AGNO-HOT-003"]
    ok = _HOT3_BAD.replace('        data = {"stage": stage}\n', "")
    assert lint_source(ok, "repro/obs/trace.py").findings == []


# ---------------------------------------------------------------------------
# AGNO-CNT-001: bare counters in metrics-instrumented classes
# ---------------------------------------------------------------------------

_CNT_BAD = """
from repro.obs import metrics as _metrics

class Bridge:
    def __init__(self):
        self._relayed = _metrics.counter("bridge.relayed")
        self.dropped = 0

    def on_drop(self):
        self.dropped += 1
"""


def test_cnt001_bare_counter():
    rep = lint_source(_CNT_BAD, "repro/core/fake.py")
    assert _rules(rep) == ["AGNO-CNT-001"]
    ok = _CNT_BAD.replace("self.dropped = 0",
                          'self.dropped = _metrics.counter("bridge.dropped")'
                          ).replace("self.dropped += 1",
                                    "self.dropped.inc()")
    assert lint_source(ok, "repro/core/fake.py").findings == []


# ---------------------------------------------------------------------------
# suppressions: must carry a justification, and are counted
# ---------------------------------------------------------------------------

def test_suppression_with_justification():
    src = _LOCK1_BAD.replace(
        "self.rows[i] = 7",
        "self.rows[i] = 7  # agnolint: allow[AGNO-LOCK-001] -- "
        "single-writer byte, folded under the next lock holder")
    rep = lint_source(src, "repro/core/fake.py")
    assert rep.findings == []
    assert len(rep.suppressions) == 1
    assert rep.suppressions[0].rule == "AGNO-LOCK-001"


def test_suppression_without_justification_is_a_finding():
    src = _LOCK1_BAD.replace(
        "self.rows[i] = 7",
        "self.rows[i] = 7  # agnolint: allow[AGNO-LOCK-001]")
    rep = lint_source(src, "repro/core/fake.py")
    assert "AGNO-SUPP-001" in _rules(rep)


# ---------------------------------------------------------------------------
# the real tree is clean (this is the CI gate, run in-process)
# ---------------------------------------------------------------------------

def test_real_tree_lints_clean():
    rep = lint_paths([os.path.join(SRC, "repro")], root=ROOT)
    assert rep.findings == [], [str(f) for f in rep.findings]
    # every suppression in the tree carries a justification
    assert all(s.justification for s in rep.suppressions)


def test_real_tree_layout_clean():
    assert check_layout([SRC]) == []


# ---------------------------------------------------------------------------
# layout drift: the v5->v6 rule — constants changed, magic not bumped
# ---------------------------------------------------------------------------

def _scratch_registry(tmp_path, transform):
    src = os.path.join(SRC, "repro", "core", "registry.py")
    with open(src, "r", encoding="utf-8") as fh:
        text = fh.read()
    out = tmp_path / "registry_scratch.py"
    out.write_text(transform(text))
    return str(out)


def test_layout_drift_without_magic_bump_fails(tmp_path):
    scratch = _scratch_registry(
        tmp_path, lambda t: t.replace("MAX_PUBS = 8", "MAX_PUBS = 16", 1))
    findings = check_layout([SRC], overrides={"registry": scratch})
    assert any(f.rule == "AGNO-LAYOUT-001"
               and "did not" in f.msg for f in findings), \
        [str(f) for f in findings]


def test_layout_drift_with_magic_bump_requires_lock_regen(tmp_path):
    def bump(t):
        t = t.replace("MAX_PUBS = 8", "MAX_PUBS = 16", 1)
        return t.replace("_MAGIC = 0xA6_0C_0D_06", "_MAGIC = 0xA6_0C_0D_07", 1)
    scratch = _scratch_registry(tmp_path, bump)
    findings = check_layout([SRC], overrides={"registry": scratch})
    assert any(f.rule == "AGNO-LAYOUT-001" and "regenerate" in f.msg
               for f in findings), [str(f) for f in findings]


def test_layout_lock_roundtrip(tmp_path):
    lock = tmp_path / "lock.json"
    write_lock([SRC], lock_path=str(lock))
    assert check_layout([SRC], lock_path=str(lock)) == []
    data = json.loads(lock.read_text())
    assert set(data) >= {"registry", "trace", "transport", "metrics"}


def test_layout_extraction_sees_the_real_constants():
    ext = extract_layout([SRC])
    reg = ext["registry"]["consts"]
    assert reg["MAX_SUBS"] == 64 and reg["MAX_PUBS"] == 8
    assert ext["trace"]["consts"]["REC_SIZE"] == 24


# ---------------------------------------------------------------------------
# interleaving checker: clean protocol passes, injected bugs are caught
# ---------------------------------------------------------------------------

def test_model_two_process_exhaustive():
    stats = model.explore(model.SCENARIOS["pub_take_release"])
    assert stats["terminals"] > 0 and stats["states"] > 500


def test_model_waiter_scenario_passes():
    stats = model.explore(model.SCENARIOS["waiter_wakeup"])
    assert stats["terminals"] > 0


def test_model_catches_missing_dekker_recheck():
    with pytest.raises(model.Violation) as ei:
        model.explore(model.SCENARIOS["waiter_wakeup"],
                      bug="no_dekker_recheck")
    assert ei.value.kind == "lost-wakeup"
    # the counterexample names the fast-path byte store it lost the race on
    assert any(".f_store" in s for s in ei.value.trace)


def test_model_catches_rollback_waiter_clobber():
    with pytest.raises(model.Violation) as ei:
        model.explore(model.SCENARIOS["waiter_wakeup"],
                      bug="rollback_clobbers_waiters")
    assert ei.value.kind in ("waiter-flag-lost", "lost-wakeup")
    assert any("kill(" in s for s in ei.value.trace)


def test_model_cli_fast_profile():
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.model",
         "--scenario", "pub_take_release", "--json"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout)
    assert out["ok"] and out["results"][0]["scenario"] == "pub_take_release"


# ---------------------------------------------------------------------------
# regression: the two real registry bugs the audit + model run found
# ---------------------------------------------------------------------------

@pytest.fixture()
def reg():
    r = Registry.create()
    yield r
    r.close()
    r.unlink()


def _dead_pid() -> int:
    p = subprocess.Popen(["true"])
    p.wait()
    return p.pid


def test_rollback_preserves_concurrent_waiter_arm(reg):
    """A publisher dying mid-transaction must not wipe another
    publisher's concurrently-armed pub_waiters flag: the restored topic
    image predates the arm, and releasers skip the slot-freed FIFO
    write when the flag reads clear — the waiter would park forever."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=2)
    j = reg._journal[t]
    # a dead writer's pending topic-image transaction, captured BEFORE
    # the waiter armed (flag = 0 in the image)
    j["topic_img"] = reg.topics[t].tobytes()
    j["pid"] = _dead_pid()
    j["tidx"], j["pidx"], j["slot"] = t, p, -1
    j["has_topic"], j["has_entry"] = 1, 0
    j["state"] = _J_PENDING
    reg.set_pub_waiter(t, p, True)          # lock-free arm, after the image
    with reg._topic_flock(t):
        reg._recover(t)
    assert reg.pub_waiter(t, p), \
        "rollback clobbered a concurrently-armed waiter flag"


def test_release_notify_uses_effective_held(reg):
    """release()'s freed decision must use the EFFECTIVE held mask: a
    sibling subscriber's lock-free release byte that lands after this
    release's fold still counts toward 'slot now publishable'.  Deciding
    on the raw mask skips the owner wakeup and strands a parked waiter
    (the sibling's fast path already returned — nobody retries)."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=2)
    sa = reg.add_subscriber(t, os.getpid())
    sb = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 0, 8)
    assert len(reg.take(t, sa)) == 1 and len(reg.take(t, sb)) == 1

    real_fold = reg._fold_releases
    state = {"armed": False}

    def fold_then_sibling_byte(tidx, pidx):
        real_fold(tidx, pidx)
        if state["armed"]:                  # B's byte lands after the fold
            reg.entries[tidx, pidx, seq % 2]["released"][sb] = 1
            state["armed"] = False

    notified = []
    reg._fold_releases = fold_then_sibling_byte
    reg._notify_owner = lambda tidx, pidx: notified.append((tidx, pidx))
    try:
        state["armed"] = True
        reg.set_pub_waiter(t, p, True)      # forces A onto the locked path
        reg.release(t, p, sa, seq)
    finally:
        reg._fold_releases = real_fold
    assert (t, p) in notified, \
        "held->0 transition hidden by an unfolded sibling release byte"


# ---------------------------------------------------------------------------
# the CLI end-to-end (strict mode over a tiny tree + JSON artifact)
# ---------------------------------------------------------------------------

def test_agnolint_cli_strict_and_json(tmp_path):
    bad = tmp_path / "repro_fake.py"
    bad.write_text(_LOCK1_BAD)
    report = tmp_path / "report.json"
    script = os.path.join(ROOT, "scripts", "agnolint.py")
    r = subprocess.run(
        [sys.executable, script, str(bad), "--strict",
         "--json", str(report)],
        capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    data = json.loads(report.read_text())
    assert data["lint"]["counts"].get("AGNO-LOCK-001") == 1
    assert data["layout"] == []     # the real tree's layout is clean
