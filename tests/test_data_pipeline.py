"""Data plane: synthetic corpus, packing, pipelines (incl. fault injection)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import BatchSpec, InProcessPipeline, ZeroCopyPipeline
from repro.data.packing import Packer, pack_documents, unpack_batch
from repro.data.synthetic import SyntheticCorpus


def test_corpus_deterministic_and_sharded():
    c = SyntheticCorpus(vocab_size=1000, seed=7)
    assert np.array_equal(c.doc(5), c.doc(5))
    assert (c.doc(5) < 1000).all()
    # shards are disjoint and cover the stream
    it0 = c.shard_iter(0, 2)
    it1 = c.shard_iter(1, 2)
    ids0 = [next(it0)[0] for _ in range(5)]
    ids1 = [next(it1)[0] for _ in range(5)]
    assert set(ids0).isdisjoint(ids1)
    assert sorted(ids0 + ids1) == list(range(10))


def test_corpus_resume_cursor():
    c = SyntheticCorpus(vocab_size=100, seed=1)
    it = c.shard_iter(0, 1)
    for _ in range(3):
        next(it)
    i3, d3 = next(it)
    it2 = c.shard_iter(0, 1, start=3)
    j3, e3 = next(it2)
    assert i3 == j3 and np.array_equal(d3, e3)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=12),
       st.integers(1, 4), st.integers(16, 64))
def test_pack_documents_properties(lengths, batch, seq_len):
    docs = [np.full(n, i + 1, np.int32) for i, n in enumerate(lengths)]
    out = pack_documents(docs, batch, seq_len)
    assert out["tokens"].shape == (batch, seq_len)
    # loss mask exactly covers nonzero segments
    assert ((out["segment_ids"] > 0) == (out["loss_mask"] > 0)).all()
    # no token invented: every non-pad token appears in some source doc
    vals = set(np.unique(out["tokens"][out["segment_ids"] > 0]).tolist())
    src = set()
    for d in docs:
        src.update(np.unique(d).tolist())
    assert vals <= src


def test_packer_emits_exact_grid():
    p = Packer(batch=2, seq_len=32)
    rng = np.random.default_rng(0)
    fed = []
    while not p.ready():
        d = rng.integers(0, 50, rng.integers(5, 40)).astype(np.int32)
        fed.append(d)
        p.feed(d)
    flat, rows = p.emit()
    assert flat.shape == (64,) and list(rows) == [32, 32]
    cat = np.concatenate(fed)
    assert np.array_equal(flat, cat[:64])  # pack-and-split preserves order
    b = unpack_batch(flat, rows, 32)
    assert b["tokens"].shape == (2, 32)
    assert (b["loss_mask"] == 1).all()


def test_inprocess_pipeline_resume():
    spec = BatchSpec(batch=2, seq_len=64, vocab_size=500, seed=3)
    p1 = InProcessPipeline(spec)
    batches = [next(p1) for _ in range(3)]
    state = p1.state()
    # restore and continue: must produce the SAME next batch
    p2 = InProcessPipeline.restore(spec, state)
    a, b = next(p1), next(p2)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert batches[0]["tokens"].shape == (2, 64)


@pytest.mark.slow
def test_zero_copy_pipeline_and_respawn():
    spec = BatchSpec(batch=2, seq_len=128, vocab_size=1000, seed=0)
    with ZeroCopyPipeline(spec, arena_mb=16) as zp:
        b1 = zp.next_batch(timeout=60)
        assert b1["tokens"].shape == (2, 128)
        assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
        # fault injection: kill the stage; next_batch must respawn + succeed
        zp.kill_stage()
        b2 = zp.next_batch(timeout=90)
        assert b2["tokens"].shape == (2, 128)
        assert zp.stats.respawns >= 1
        # zero-copy hand-off latency was recorded
        assert zp.feeder.hand_off_latency
