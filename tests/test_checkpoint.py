"""Checkpointer: atomic commit, GC, async errors, restore + reshard."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step


def _state(k=0):
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4) + k,
                       "b": jnp.ones((4,)) * k},
            "step": jnp.int32(k)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, _state(5), extra={"data_cursor": 17})
    abstract = jax.eval_shape(_state)
    got, step, extra = ck.restore(abstract)
    assert step == 5 and extra["data_cursor"] == 17
    assert np.array_equal(got["params"]["w"], np.asarray(_state(5)["params"]["w"]))


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2  # keep=2
    got, step, _ = ck.restore(jax.eval_shape(_state))
    assert step == 4 and float(got["params"]["b"][0]) == 4.0


def test_async_save_overlaps_and_waits(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, _state(1))
    ck.wait()
    assert latest_step(str(tmp_path)) == 1


def test_atomic_no_partial_pickup(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _state(1))
    # simulate a crash mid-save: a stale tmp dir must be ignored by restore
    stale = os.path.join(tmp_path, "step_0000000002.tmp-999")
    os.makedirs(stale)
    with open(os.path.join(stale, "manifest.json"), "w") as f:
        json.dump({"step": 2}, f)
    assert latest_step(str(tmp_path)) == 1
    _, step, _ = ck.restore(jax.eval_shape(_state))
    assert step == 1


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _state(1))
    bad = jax.eval_shape(lambda: {"params": {"w": jnp.zeros((2, 2)),
                                             "b": jnp.zeros((4,))},
                                  "step": jnp.int32(0)})
    with pytest.raises(ValueError, match="shape"):
        ck.restore(bad)


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, _state(3))
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                      jax.eval_shape(_state))
    got, step, _ = ck.restore(jax.eval_shape(_state), shardings=sh)
    assert step == 3
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())
