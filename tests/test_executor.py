"""EventExecutor: multi-topic fan-in, callback groups, deterministic ptr
release, cross-process wakeup — plus the Registry WAL-replay property test
(the metadata plane the executor rides on)."""

import multiprocessing as mp
import os
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import _mp_helpers as H
from repro.core import (
    POINT_CLOUD2,
    AgnocastQueueFull,
    Domain,
    EventExecutor,
    MutuallyExclusiveCallbackGroup,
    ReentrantCallbackGroup,
    Registry,
)
from repro.core.registry import ST_USED, _J_CLEAN, _J_PENDING


@pytest.fixture()
def dom():
    d = Domain.create(arena_capacity=32 << 20)
    yield d
    d.close()


def _publish(pub, payload):
    m = pub.borrow_loaded_message()
    m.data.extend(np.asarray(payload, np.uint8))
    return pub.publish(m)


# ---------------------------------------------------------------------------
# in-process mode
# ---------------------------------------------------------------------------


def test_multi_topic_fanin_delivery_order(dom):
    """One executor over K topics: every message arrives exactly once and
    per-topic seq order is preserved (batched takes claim lowest seq first)."""
    k, per = 3, 5
    pubs = [dom.create_publisher(POINT_CLOUD2, f"t{i}", depth=16)
            for i in range(k)]
    subs = [dom.create_subscription(POINT_CLOUD2, f"t{i}") for i in range(k)]
    got: list[tuple[int, int]] = []
    with EventExecutor() as ex:
        for i, s in enumerate(subs):
            ex.add_subscription(s, lambda ptr, i=i: got.append((i, ptr.seq)))
        for n in range(per):
            for i, p in enumerate(pubs):
                _publish(p, np.full(8, i + n, np.uint8))
        ex.spin(until=lambda: len(got) >= k * per, timeout=10)
    assert len(got) == k * per
    for i in range(k):
        seqs = [seq for (t, seq) in got if t == i]
        assert seqs == sorted(seqs) and len(seqs) == per
    for p in pubs:
        p.reclaim()
    assert dom.arena.live_bytes == 0  # executor released every ptr


def test_executor_releases_after_callback(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    kept = []
    with EventExecutor() as ex:
        ex.add_subscription(sub, lambda ptr: kept.append(ptr.clone()))
        _publish(pub, np.arange(16, dtype=np.uint8))
        ex.spin(until=lambda: kept, timeout=10)
        assert pub.reclaim() == 0      # clone still holds the reference
        kept.pop().release()
        assert pub.reclaim() == 1      # now both counters are zero
    assert dom.arena.live_bytes == 0


def test_batched_take_limit_repolls(dom):
    """A batch cap smaller than the burst must not strand messages (the
    wake tokens are drained on the first take)."""
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=16)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    got = []
    with EventExecutor() as ex:
        ex.add_subscription(sub, lambda ptr: got.append(ptr.seq), batch=2)
        for n in range(7):
            _publish(pub, np.full(4, n, np.uint8))
        ex.spin(until=lambda: len(got) >= 7, timeout=10)
    assert got == sorted(got) and len(got) == 7


def test_unregister_releases_pending_ptrs(dom):
    """Undispatched work discarded at unregister must release its
    MessagePtrs immediately (held bits dropped, ring slots freeable)."""
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    ex = EventExecutor()
    h = ex.add_subscription(sub, lambda ptr: None)
    _publish(pub, np.ones(8, np.uint8))
    _publish(pub, np.ones(8, np.uint8))
    # claim + enqueue without dispatching (what a loop iteration does first)
    works = h._on_ready(sub.fileno())
    assert len(works) == 2
    ex._enqueue(works)
    dropped = ex.unregister(h)
    assert dropped == 2
    assert pub.reclaim() == 2          # released deterministically
    ex.shutdown()
    assert dom.arena.live_bytes == 0


def test_shutdown_discards_pending_deterministically(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    ex = EventExecutor()
    h = ex.add_subscription(sub, lambda ptr: None)
    _publish(pub, np.ones(8, np.uint8))
    ex._enqueue(h._on_ready(sub.fileno()))
    assert ex.shutdown() == 1          # the queued ptr was discarded...
    assert pub.reclaim() == 1          # ...and its reference released
    assert dom.arena.live_bytes == 0


def test_dead_publisher_fifo_parked_not_spun(dom):
    """When every publisher closes the wakeup FIFO's write end the fd goes
    permanently POLLHUP-readable; the executor must park it on the slow
    re-poll timer instead of hot-looping epoll — and still deliver from a
    publisher that joins later."""
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    got = []
    with EventExecutor() as ex:
        ex.add_subscription(sub, lambda ptr: got.append(ptr.seq))
        _publish(pub, np.ones(4, np.uint8))
        ex.spin(until=lambda: got, timeout=10)
        pub.close()                      # last writer gone -> EOF
        ex.spin_once(0.2)                # observes hangup
        assert sub.fileno() not in ex._sel.get_map()  # parked, not polled
        assert ex._timers                # slow re-poll armed
        pub2 = dom.create_publisher(POINT_CLOUD2, "t", depth=4)
        _publish(pub2, np.full(4, 2, np.uint8))
        ex.spin(until=lambda: len(got) >= 2, timeout=10)
    assert got == [1, 1]  # independent per-publisher sequences


def test_timer_fires_periodically(dom):
    ticks = []
    with EventExecutor() as ex:
        ex.add_timer(0.01, lambda: ticks.append(time.monotonic()))
        ex.spin(until=lambda: len(ticks) >= 3, timeout=5)
    assert len(ticks) >= 3


# ---------------------------------------------------------------------------
# threaded mode + callback groups
# ---------------------------------------------------------------------------


def test_mutually_exclusive_group_threaded(dom):
    """Callbacks of one ME group never overlap even with a worker pool."""
    pubs = [dom.create_publisher(POINT_CLOUD2, f"m{i}", depth=16)
            for i in range(2)]
    subs = [dom.create_subscription(POINT_CLOUD2, f"m{i}") for i in range(2)]
    lock = threading.Lock()
    conc = {"cur": 0, "max": 0}
    done = []

    def cb(ptr):
        with lock:
            conc["cur"] += 1
            conc["max"] = max(conc["max"], conc["cur"])
        time.sleep(0.01)
        with lock:
            conc["cur"] -= 1
        done.append(ptr.seq)

    ex = EventExecutor(threads=4).start()
    group = MutuallyExclusiveCallbackGroup("me")
    for s in subs:
        ex.add_subscription(s, cb, group=group)
    for n in range(3):
        for p in pubs:
            _publish(p, np.full(4, n, np.uint8))
    deadline = time.monotonic() + 10
    while len(done) < 6 and time.monotonic() < deadline:
        time.sleep(0.005)
    ex.shutdown()
    assert len(done) == 6
    assert conc["max"] == 1


def test_reentrant_group_runs_concurrently(dom):
    """Reentrant group on a worker pool: two callbacks must overlap (each
    waits on a barrier only the other can complete)."""
    pubs = [dom.create_publisher(POINT_CLOUD2, f"r{i}", depth=8)
            for i in range(2)]
    subs = [dom.create_subscription(POINT_CLOUD2, f"r{i}") for i in range(2)]
    barrier = threading.Barrier(2, timeout=5)
    met = []

    def cb(ptr):
        barrier.wait()                 # deadlocks unless both run at once
        met.append(ptr.seq)

    ex = EventExecutor(threads=4).start()
    group = ReentrantCallbackGroup("re")
    for s in subs:
        ex.add_subscription(s, cb, group=group)
    for p in pubs:
        _publish(p, np.ones(4, np.uint8))
    deadline = time.monotonic() + 10
    while len(met) < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    ex.shutdown()
    assert len(met) == 2


def test_bridge_on_executor(dom):
    """A Bridge registered on the executor relays both directions from one
    epoll loop (agnocast FIFO + bus socket multiplexed together)."""
    from repro.core import Bridge, Bus, BusClient, deserialize, serialize

    bus = Bus().start()
    try:
        bridge = Bridge(dom, bus.path, POINT_CLOUD2, "pc")
        rosish = BusClient(bus.path)
        rosish.subscribe("pc")
        app_sub = dom.create_subscription(POINT_CLOUD2, "pc")
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        time.sleep(0.2)
        agno_in = []
        with EventExecutor() as ex:
            bridge.register(ex)
            ex.add_subscription(app_sub, lambda ptr: agno_in.append(
                np.asarray(ptr.data).copy()))
            # agnocast -> bus
            _publish(pub, np.arange(48, dtype=np.uint8))
            ex.spin(until=lambda: bridge.relayed_out >= 1, timeout=10)
            got = rosish.recv(timeout=10)
            assert got is not None and got[1] == 1  # bridge-tagged origin
            assert np.array_equal(deserialize(got[2])["data"],
                                  np.arange(48, dtype=np.uint8))
            # bus -> agnocast
            pm = POINT_CLOUD2.plain()
            pm.data = np.full(16, 9, np.uint8)
            rosish.publish("pc", serialize(pm), origin=0)
            # app_sub also saw the agnocast-origin message from direction 1
            ex.spin(until=lambda: any(a.shape[0] == 16 for a in agno_in),
                    timeout=10)
        assert any(np.array_equal(a, np.full(16, 9, np.uint8))
                   for a in agno_in)
        rosish.close()
        bridge.close()
    finally:
        bus.stop()


# ---------------------------------------------------------------------------
# event-driven backpressure (slot-freed reverse FIFO)
# ---------------------------------------------------------------------------


def test_wait_for_slot_event_driven(dom):
    """A publisher blocked on a full ring is woken by the releaser's FIFO
    write — no polling, and well before a poll interval would fire."""
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=2)
    sub = dom.create_subscription(POINT_CLOUD2, "t")
    _publish(pub, np.ones(8, np.uint8))
    _publish(pub, np.ones(8, np.uint8))
    held = sub.take()
    assert len(held) == 2
    with pytest.raises(AgnocastQueueFull):
        m = pub.borrow_loaded_message()
        m.data.extend(np.ones(8, np.uint8))
        pub.publish(m)
    assert not pub.wait_for_slot(timeout=0.05)   # nothing released yet

    t_rel = []

    def releaser():
        time.sleep(0.15)
        t_rel.append(time.monotonic())
        held[0].release()                        # frees the target slot

    th = threading.Thread(target=releaser)
    th.start()
    assert pub.wait_for_slot(timeout=5.0)
    woke = time.monotonic()
    th.join()
    assert woke - t_rel[0] < 0.1                 # event wake, not a timeout
    pub.publish(m)                               # the retried publish lands
    held[1].release()
    for ptr in sub.take():
        ptr.release()
    pub.reclaim()


def test_slot_fifo_immune_to_departing_releaser(dom):
    """A releaser opening and closing the write end (what Registry.close
    does when a subscriber process exits) must not leave the publisher's
    slot-freed fd permanently EOF-readable — that would turn every
    wait_for_slot / executor pub-fd wait into a hot spin."""
    import select as _select

    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=2)
    from repro.core.registry import pub_fifo_path
    path = pub_fifo_path(dom.name, pub.tidx, pub.pidx)
    w = os.open(path, os.O_WRONLY | os.O_NONBLOCK)
    os.write(w, b"\x01")
    os.close(w)                              # the releaser process exits
    pub.drain_slot_wakeups()
    # no writer left: the fd must be silent, not permanently readable
    r, _, _ = _select.select([pub.fileno()], [], [], 0.2)
    assert not r
    # and a fresh wakeup still lands afterwards
    w = os.open(path, os.O_WRONLY | os.O_NONBLOCK)
    os.write(w, b"\x01")
    r, _, _ = _select.select([pub.fileno()], [], [], 2.0)
    assert r
    os.close(w)


def test_wait_for_slot_wakes_despite_lagging_subscriber(dom):
    """publish blocks only on *held* occupants (unreceived-only ones are
    QoS-dropped), so the held->0 transition must wake the blocked publisher
    even while a second, slow subscriber has not taken the entry yet."""
    pub = dom.create_publisher(POINT_CLOUD2, "t", depth=2)
    fast = dom.create_subscription(POINT_CLOUD2, "t")
    slow = dom.create_subscription(POINT_CLOUD2, "t")   # never takes
    _publish(pub, np.ones(8, np.uint8))
    _publish(pub, np.ones(8, np.uint8))
    held = fast.take()
    assert len(held) == 2
    assert not pub.wait_for_slot(timeout=0.05)

    def releaser():
        time.sleep(0.15)
        held[0].release()   # held -> 0 on the target slot; slow still lags

    th = threading.Thread(target=releaser)
    th.start()
    assert pub.wait_for_slot(timeout=2.0)   # a lost wakeup would time out
    th.join()
    held[1].release()
    slow.close()
    for ptr in fast.take():
        ptr.release()
    pub.reclaim()


def test_cross_process_blocked_publisher_wakeup():
    """Executor-multiplexed backpressure across processes: a child holds
    every ring slot; its release must wake this process's blocked publisher
    through the slot-freed FIFO *inside the executor loop*."""
    ctx = mp.get_context("spawn")
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "bp", depth=2)
        q_out, q_in = ctx.Queue(), ctx.Queue()
        child = ctx.Process(target=H.holding_releaser,
                            args=(dom.name, "bp", q_out, q_in), daemon=True)
        child.start()
        assert q_out.get(timeout=15) == "ready"
        _publish(pub, np.full(8, 1, np.uint8))
        _publish(pub, np.full(8, 2, np.uint8))
        assert q_out.get(timeout=15) == "holding"
        pending = pub.borrow_loaded_message()
        pending.data.extend(np.full(8, 3, np.uint8))
        with pytest.raises(AgnocastQueueFull):
            pub.publish(pending)

        woken = []

        def on_slot_freed(p):
            p.reclaim()
            if pending is not None and not woken:
                p.publish(pending)
                woken.append(time.monotonic())

        with EventExecutor() as ex:
            ex.add_publisher(pub, on_slot_freed)
            ex.spin_once(0.1)
            t_ask = time.monotonic()
            q_in.put("release")                  # child drops both refs
            ex.spin(until=lambda: woken, timeout=15)
            assert q_out.get(timeout=15) == "released"
        assert woken and woken[0] - t_ask < 5.0
        assert int(dom.registry.topics[pub.tidx]["pub_next_seq"][pub.pidx]) == 4
        q_in.put("done")
        child.join(timeout=10)
        dom.sweep()
        pub.reclaim()
    finally:
        dom.close()


# ---------------------------------------------------------------------------
# cross-process mode
# ---------------------------------------------------------------------------


def test_cross_process_executor_wakeup():
    """K publishers in this process, one executor in a child: FIFO wakeups
    cross the process boundary and fan into one epoll loop."""
    ctx = mp.get_context("spawn")
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        topics = ["xa", "xb", "xc"]
        pubs = {t: dom.create_publisher(POINT_CLOUD2, t, depth=8)
                for t in topics}
        q = ctx.Queue()
        child = ctx.Process(target=H.executor_subscriber,
                            args=(dom.name, topics, q, 6), daemon=True)
        child.start()
        assert q.get(timeout=15) == "ready"
        for n in range(2):
            for i, t in enumerate(topics):
                _publish(pubs[t], np.full(10, 10 * i + n, np.uint8))
                time.sleep(0.01)
        recs = [q.get(timeout=15) for _ in range(6)]
        assert q.get(timeout=15) == "done"
        child.join(timeout=10)
        by_topic = {t: [seq for (tt, seq, _) in recs if tt == t]
                    for t in topics}
        for t, i in zip(topics, range(3)):
            assert by_topic[t] == [1, 2]
        sums = sorted(s for (_, _, s) in recs)
        assert sums == sorted(10 * (10 * i + n)
                              for i in range(3) for n in range(2))
        dom.sweep()
        for p in pubs.values():
            p.reclaim()
        assert dom.arena.live_bytes == 0
    finally:
        dom.close()


# ---------------------------------------------------------------------------
# metadata plane: WAL replay always converges to the janitor-cleaned state
# ---------------------------------------------------------------------------

_DEAD_PID = 2**22 + 4242  # beyond pid_max defaults: certainly not alive


@settings(max_examples=30, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("pub"), st.integers(1, 512)),
            st.tuples(st.just("take"), st.integers(0, 4)),
            st.tuples(st.just("release"), st.integers(0, 3)),
        ),
        max_size=30,
    ),
    crash_slot=st.integers(0, 3),
)
def test_wal_replay_converges_to_janitor_state(ops, crash_slot):
    """Any op sequence, then a simulated crash (dead subscriber holding refs
    + a torn in-flight mutation left PENDING in the WAL): recovery + one
    janitor sweep must yield a clean, self-consistent, *stable* state."""
    reg = Registry.create()
    j = ring = None
    try:
        t = reg.topic_index("x")
        p = reg.add_publisher(t, os.getpid(), "a", depth=4)
        s = reg.add_subscriber(t, os.getpid())
        taken = []
        seen = set()
        for kind, arg in ops:
            if kind == "pub":
                try:
                    reg.publish(t, p, arg, 1)
                except AgnocastQueueFull:
                    pass
            elif kind == "take":
                got = reg.take(t, s, limit=arg or None)
                assert [e.seq for e in got] == sorted(e.seq for e in got)
                assert not seen.intersection(e.seq for e in got)  # exactly once
                seen.update(e.seq for e in got)
                taken.extend(got)
            elif kind == "release" and taken:
                e = taken.pop(arg % len(taken))
                reg.release(t, p, s, e.seq)

        # the crash: subscriber dies holding refs; a writer dies mid-mutation
        before = reg.entries[t, p, crash_slot].copy()
        j = reg._journal[0]
        j["pid"] = _DEAD_PID
        j["tidx"], j["pidx"], j["slot"] = t, p, crash_slot
        j["has_topic"], j["has_entry"] = 0, 1
        j["entry_img"] = before.tobytes()
        j["state"] = _J_PENDING
        reg.entries[t, p, crash_slot]["desc_off"] = 31337       # torn write
        reg.topics[t]["sub_pids"][s] = _DEAD_PID                # dead holder

        reg.sweep()  # lock acquisition replays the WAL, janitor cleans

        # 1. WAL is clean and the torn write was rolled back
        assert int(reg._journal[0]["state"]) == _J_CLEAN
        assert (int(reg.entries[t, p, crash_slot]["desc_off"])
                == int(before["desc_off"]))
        # 2. no reference or unreceived bit of any dead subscriber survives
        alive = int(reg.topics[t]["sub_alive"])
        ring = reg.entries[t, p]
        for sl in range(4):
            assert int(ring[sl]["held"]) & ~alive == 0
            assert int(ring[sl]["unreceived"]) & ~alive == 0
        # 3. with the only subscriber dead, every used entry is reclaimable
        freed = reg.reclaimable(t, p)
        assert not np.any(ring["state"] == ST_USED)
        assert sorted(freed) == sorted(set(freed))
        # 4. convergence: a second sweep is a no-op (fixed point).  The
        # seqlock write counter is excluded: it advances on every locked
        # section by design, even when the section changes nothing.
        def _logical_image():
            row = reg.topics[t].copy()
            row["wseq"] = 0
            return row.tobytes() + reg.entries[t].tobytes()

        img = _logical_image()
        rep = reg.sweep()
        assert rep["dead_subs"] == 0 and rep["dead_pubs"] == 0
        assert img == _logical_image()
    finally:
        j = ring = None  # drop shm views so close() can release the mapping
        reg.close()
        reg.unlink()


# ---------------------------------------------------------------------------
# waiter flag: releasers skip the FIFO syscall when nobody is blocked
# ---------------------------------------------------------------------------


def test_release_skips_fifo_write_without_waiter(dom):
    """A release with no blocked publisher must NOT write the slot-freed
    FIFO (the hot-path syscall the waiter flag removes); with the flag up
    the very same release must."""
    import select as _select

    pub = dom.create_publisher(POINT_CLOUD2, "w", depth=2)
    sub = dom.create_subscription(POINT_CLOUD2, "w")
    _publish(pub, np.ones(8, np.uint8))
    _publish(pub, np.ones(8, np.uint8))
    held = sub.take()
    assert len(held) == 2
    held[0].release()                       # waiter flag is clear
    r, _, _ = _select.select([pub.fileno()], [], [], 0.1)
    assert not r                            # no wakeup byte was written
    pub.set_waiting(True)                   # now we are "blocked"
    held[1].release()
    r, _, _ = _select.select([pub.fileno()], [], [], 2.0)
    assert r                                # the release woke us
    pub.set_waiting(False)
    pub.drain_slot_wakeups()
    pub.reclaim()


def test_wait_for_slot_toggles_waiter_flag(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "w2", depth=2)
    flag = lambda: int(dom.registry.topics[pub.tidx]["pub_waiters"][pub.pidx])
    assert flag() == 0
    assert pub.wait_for_slot(timeout=0.01)  # ring empty: returns at once
    assert flag() == 0                      # cleared on the way out
    sub = dom.create_subscription(POINT_CLOUD2, "w2")
    _publish(pub, np.ones(4, np.uint8))
    _publish(pub, np.ones(4, np.uint8))
    held = sub.take()
    assert not pub.wait_for_slot(timeout=0.05)   # blocked: times out...
    assert flag() == 0                           # ...and still cleared
    for p in held:
        p.release()
    pub.reclaim()


def test_add_publisher_arms_waiter_flag_for_handle_lifetime(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "w3", depth=2)
    flag = lambda: int(dom.registry.topics[pub.tidx]["pub_waiters"][pub.pidx])
    ex = EventExecutor()
    h = ex.add_publisher(pub, lambda p: None)
    assert flag() == 1                      # handle waits on our behalf
    ex.unregister(h)
    assert flag() == 0                      # detach cleared it
    h2 = ex.add_publisher(pub, lambda p: None)
    assert flag() == 1
    ex.shutdown()                           # shutdown also detaches
    assert flag() == 0


# ---------------------------------------------------------------------------
# drain(): clean-shutdown hook (pending work runs, nothing new is awaited)
# ---------------------------------------------------------------------------


def test_drain_runs_pending_work_then_returns(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "d", depth=8)
    sub = dom.create_subscription(POINT_CLOUD2, "d")
    got = []
    ex = EventExecutor()
    ex.add_subscription(sub, lambda ptr: got.append(ptr.seq))
    for n in range(3):
        _publish(pub, np.full(4, n, np.uint8))
    assert ex.drain(5.0)                    # no spin(): drain alone delivers
    assert got == [1, 2, 3]
    # idle executor: drain is an immediate no-op
    t0 = time.monotonic()
    assert ex.drain(5.0)
    assert time.monotonic() - t0 < 1.0
    ex.shutdown()
    pub.reclaim()
    assert dom.arena.live_bytes == 0


def test_drain_threaded_waits_for_workers(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "dt", depth=8)
    sub = dom.create_subscription(POINT_CLOUD2, "dt")
    done = []

    def slow(ptr):
        time.sleep(0.05)
        done.append(ptr.seq)

    ex = EventExecutor(threads=2)
    ex.add_subscription(sub, slow)
    for n in range(4):
        _publish(pub, np.full(4, n, np.uint8))
    assert ex.drain(10.0)
    assert sorted(done) == [1, 2, 3, 4]     # workers finished before return
    ex.shutdown()
    pub.reclaim()
