"""Trip-count-aware HLO cost analysis: validated against known-exact cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_exact():
    """L matmuls under lax.scan: XLA's cost_analysis reports ONE body; the
    analyzer must recover the full L x 2 x 128^3."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    for L in (3, 11):
        txt = _compile_text(
            f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((L, 128, 128), jnp.float32))
        c = analyze_hlo(txt)
        assert c.unresolved_whiles == 0
        np.testing.assert_allclose(c.flops, L * 2 * 128**3, rtol=1e-6)


def test_nested_scan_flops_exact():
    """Outer scan of G groups, inner scan of K matmuls: flops = G*K*2*64^3."""
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        y, _ = jax.lax.scan(inner, x, ws)
        return y, None

    def f(x, ws):
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    G, K = 4, 3
    txt = _compile_text(
        f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((G, K, 64, 64), jnp.float32))
    c = analyze_hlo(txt)
    assert c.unresolved_whiles == 0
    np.testing.assert_allclose(c.flops, G * K * 2 * 64**3, rtol=1e-6)


def test_unrolled_matches_scanned():
    """The same model unrolled vs scanned must yield (nearly) the same
    analyzer flops — the whole point of trip scaling."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(6):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    cs = analyze_hlo(_compile_text(scanned, x, ws))
    cu = analyze_hlo(_compile_text(unrolled, x, ws))
    np.testing.assert_allclose(cs.flops, cu.flops, rtol=1e-6)
    # bytes agree within 2x (scan carries loop state through HBM)
    assert 0.5 < cs.bytes / cu.bytes < 2.5


def test_collectives_scaled_by_trips():
    """A psum inside a scan body must be counted once per trip.

    Needs >1 device, so it runs in a subprocess with 8 forced host devices
    (the test process itself keeps the 1-device default)."""
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((8,), ("d",))
L = 7
def f(xs):
    def body(c, x):
        y = jax.lax.with_sharding_constraint(x * 2.0, NamedSharding(mesh, P()))
        return c + y.sum(), None
    return jax.lax.scan(body, 0.0, xs)[0]
xs = jax.ShapeDtypeStruct((L, 8, 128), jnp.float32)
with mesh:
    txt = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),)) \\
        .lower(xs).compile().as_text()
c = analyze_hlo(txt, world=8)
n_ar = c.collectives["all-reduce"]["count"] + c.collectives["all-gather"]["count"]
assert n_ar >= L, (n_ar, c.collectives)
print("OK", n_ar)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=240,
                         env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.startswith("OK")


def test_decode_dus_not_charged_full_cache():
    """dynamic-update-slice must count the updated window, not the cache.

    The cache is donated — otherwise XLA inserts a defensive full copy
    (which the analyzer would rightly charge)."""
    def f(cache, tok):
        return jax.lax.dynamic_update_slice(cache, tok, (0, 5))

    cache = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MB
    tok = jax.ShapeDtypeStruct((1024, 1), jnp.float32)       # 4 KB
    txt = (jax.jit(f, donate_argnums=(0,))
           .lower(cache, tok).compile().as_text())
    c = analyze_hlo(txt)
    assert c.bytes < 1024 * 1024 * 4  # far less than one full-cache pass
