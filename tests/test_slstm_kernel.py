"""slstm_scan Pallas kernel vs the pure-jnp oracle: shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm_scan import slstm_scan, slstm_scan_ref


@pytest.mark.parametrize("B,S,D,H,bb,sc", [
    (1, 16, 32, 2, 1, 16),     # single tile
    (3, 40, 64, 4, 2, 16),     # batch + seq padding
    (2, 33, 48, 4, 2, 32),     # odd seq
    (4, 64, 64, 1, 4, 16),     # single head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_slstm_scan_matches_oracle(B, S, D, H, bb, sc, dtype):
    rng = np.random.default_rng(B * 1000 + S)
    xg = jnp.asarray(rng.normal(size=(B, S, 4 * D)), dtype)
    whh = jnp.asarray(rng.normal(size=(H, D // H, 4 * (D // H))) * 0.2, dtype)
    b = jnp.asarray(rng.normal(size=(4 * D,)) * 0.1, jnp.float32)
    z = jnp.zeros((B, D), jnp.float32)
    m0 = jnp.full((B, D), -jnp.inf, jnp.float32)

    hs_k, st_k = slstm_scan(xg, whh, b, z, z, z, m0,
                            block_batch=bb, seq_chunk=sc)
    hs_r, st_r = slstm_scan_ref(xg, whh, b, z, z, z, m0)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r),
                               rtol=tol, atol=tol)
    for a, c, name in zip(st_k, st_r, "hcnm"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=tol, atol=tol, err_msg=name)


def test_slstm_scan_resumes_from_state():
    """Running [0:S1] then [S1:S] from the carried state == one pass."""
    rng = np.random.default_rng(7)
    B, S, D, H = 2, 24, 32, 2
    xg = jnp.asarray(rng.normal(size=(B, S, 4 * D)), jnp.float32)
    whh = jnp.asarray(rng.normal(size=(H, D // H, 4 * (D // H))) * 0.2,
                      jnp.float32)
    b = jnp.asarray(rng.normal(size=(4 * D,)) * 0.1, jnp.float32)
    z = jnp.zeros((B, D), jnp.float32)
    m0 = jnp.full((B, D), -jnp.inf, jnp.float32)

    hs_full, st_full = slstm_scan(xg, whh, b, z, z, z, m0, seq_chunk=8)
    hs_a, st_a = slstm_scan(xg[:, :16], whh, b, z, z, z, m0, seq_chunk=8)
    hs_b, st_b = slstm_scan(xg[:, 16:], whh, b, *st_a, seq_chunk=8)
    np.testing.assert_allclose(np.asarray(hs_full[:, 16:]),
                               np.asarray(hs_b), rtol=1e-5, atol=1e-5)
    for a, c in zip(st_full, st_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-5, atol=1e-5)
