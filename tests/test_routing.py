"""Federated routing plane: longest-prefix rules, 3-domain exactly-once
delivery (hub + cyclic topologies), relay-through with route metadata,
copy-in abort safety, and event-driven bridge backpressure."""

import os
import time

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    POINT_CLOUD2,
    Bus,
    BusClient,
    Domain,
    DomainBridge,
    EventExecutor,
    Router,
    RoutingTable,
    domain_tag,
    serialize,
)


# ---------------------------------------------------------------------------
# routing table
# ---------------------------------------------------------------------------


def test_routing_table_longest_prefix_selection():
    t = RoutingTable()
    t.add("sensing/", "b")
    t.add("sensing/", "c")
    t.add("sensing/top", "c")
    t.add("planning/", "b")
    # tie at the same (longest) prefix: both remotes federate
    assert t.lookup("sensing/left/points") == ["b", "c"]
    # longer prefix shadows the shorter rules entirely
    assert t.lookup("sensing/top/points") == ["c"]
    assert t.lookup("planning/route") == ["b"]
    assert t.lookup("unrouted/topic") == []
    # match() exposes the single winning rule
    assert t.match("sensing/top/points").prefix == "sensing/top"


def test_routing_table_blackhole_keeps_local():
    t = RoutingTable()
    t.add("", "b")                   # default route: everything federates
    t.add("sensing/private", None)   # ...except this subtree
    assert t.lookup("sensing/points") == ["b"]
    assert t.lookup("sensing/private/raw") == []


_PREFIXES = ["", "s/", "s/a", "s/a/b", "s/b", "t/", "t/a"]
_REMOTES = ["r1", "r2", "r3", None]
_TOPICS = ["s/a/b/c", "s/a", "s/b/x", "t/a/y", "t/z", "u/v"]


@settings(max_examples=30, deadline=None)
@given(
    rules=st.lists(st.tuples(st.sampled_from(_PREFIXES),
                             st.sampled_from(_REMOTES)), max_size=8),
    topic=st.sampled_from(_TOPICS),
)
def test_routing_table_lookup_matches_bruteforce(rules, topic):
    """lookup() == brute force over the rule list: remotes at the longest
    matching prefix, deduped in insertion order, blackhole shadows all."""
    t = RoutingTable()
    for p, r in rules:
        t.add(p, r)
    matching = [(p, r) for p, r in rules if topic.startswith(p)]
    if not matching:
        expected = []
    else:
        longest = max(len(p) for p, _ in matching)
        at_best = [r for p, r in matching if len(p) == longest]
        expected = []
        if not any(r is None for r in at_best):
            for r in at_best:
                if r not in expected:
                    expected.append(r)
    assert t.lookup(topic) == expected


# ---------------------------------------------------------------------------
# federation topologies (in-process domains, real buses)
# ---------------------------------------------------------------------------


def _mk_router(dom, links, prefix="sensing/"):
    r = Router(dom)
    for name, path in links:
        r.add_remote(name, path, depth=8)
        r.add_route(prefix, name)
    return r


def _publish(pub, value, n=32):
    m = pub.borrow_loaded_message()
    m.data.extend(np.full(n, value, np.uint8))
    m.set("stamp", time.monotonic())
    pub.reclaim()
    pub.publish_blocking(m, timeout=10.0)


def test_three_domain_hub_exactly_once():
    """One shared bus, three domains: a message published in A reaches B and
    C exactly once each (and A's own plane untouched by the relay)."""
    topic = "sensing/pc"
    bus = Bus().start()
    doms = {k: Domain.create(arena_capacity=16 << 20) for k in "ABC"}
    try:
        routers = {}
        for k, d in doms.items():
            r = _mk_router(d, [("hub", bus.path)])
            r.activate(POINT_CLOUD2, topic)
            routers[k] = r
        pub = doms["A"].create_publisher(POINT_CLOUD2, topic, depth=8)
        got = {k: [] for k in "BC"}
        ex = EventExecutor(name="hub")
        for k in "BC":
            sub = doms[k].create_subscription(POINT_CLOUD2, topic)
            ex.add_subscription(
                sub, lambda ptr, k=k: got[k].append(int(np.asarray(ptr.data)[0])))
        for r in routers.values():
            r.register(ex)
        time.sleep(0.3)  # bus SUB frames must land before data flows
        for i in range(5):
            _publish(pub, i)
        ex.spin(until=lambda: all(len(v) >= 5 for v in got.values()),
                timeout=20)
        # keep spinning: any ping-pong/duplicate would surface now
        ex.spin(timeout=0.5)
        ex.shutdown()
        assert got["B"] == [0, 1, 2, 3, 4]
        assert got["C"] == [0, 1, 2, 3, 4]
    finally:
        for r in routers.values():
            r.close()
        for d in doms.values():
            d.close()
        bus.stop()


def test_cyclic_ring_exactly_once_no_ping_pong():
    """A ring (A-B, B-C, C-A buses) has two paths to every domain and a
    cycle back to the origin: dedup must deliver exactly once per remote
    domain and the origin tag must stop the returning copies."""
    topic = "sensing/pc"
    buses = {n: Bus().start() for n in ("ab", "bc", "ca")}
    links = {"A": ("ab", "ca"), "B": ("ab", "bc"), "C": ("bc", "ca")}
    doms = {k: Domain.create(arena_capacity=16 << 20) for k in "ABC"}
    try:
        routers = {}
        for k, d in doms.items():
            r = _mk_router(d, [(n, buses[n].path) for n in links[k]])
            r.activate(POINT_CLOUD2, topic)
            routers[k] = r
        pub = doms["A"].create_publisher(POINT_CLOUD2, topic, depth=8)
        subs = {k: doms[k].create_subscription(POINT_CLOUD2, topic)
                for k in "BC"}
        got = {k: [] for k in "BC"}
        time.sleep(0.3)
        for i in range(4):
            _publish(pub, i)
        deadline = time.monotonic() + 20
        # deterministic round-robin pump (standalone mode) until settled
        while time.monotonic() < deadline:
            moved = sum(r.spin_once(0.01) for r in routers.values())
            for k, s in subs.items():
                for ptr in s.take():
                    got[k].append(int(np.asarray(ptr.data)[0]))
                    ptr.release()
            if all(len(v) >= 4 for v in got.values()) and moved == 0:
                break
        # extra settling: ping-pong or duplicates would show up here
        for _ in range(30):
            for r in routers.values():
                r.spin_once(0.005)
        for k, s in subs.items():
            for ptr in s.take():
                got[k].append(int(np.asarray(ptr.data)[0]))
                ptr.release()
        assert sorted(got["B"]) == [0, 1, 2, 3]
        assert sorted(got["C"]) == [0, 1, 2, 3]
        # the loop-prevention machinery actually fired: the origin dropped
        # returning copies, and every domain saw the second path's copy once
        drops = {k: sum(br.dropped_loops for br in routers[k].bridges.values())
                 for k in "ABC"}
        dups = sum(br.dropped_dups for r in routers.values()
                   for br in r.bridges.values())
        assert drops["A"] > 0          # copies that came back to the origin
        assert dups > 0                # second-path copies were deduped
    finally:
        for r in routers.values():
            r.close()
        for d in doms.values():
            d.close()
        for b in buses.values():
            b.stop()


def test_chain_relay_through_middle_domain_route_metadata():
    """A ── B ── C chain: B relays through its own zero-copy plane; C's copy
    carries the origin tag and a 2-bus-hop count."""
    topic = "sensing/pc"
    bus_ab, bus_bc = Bus().start(), Bus().start()
    doms = {k: Domain.create(arena_capacity=16 << 20) for k in "ABC"}
    try:
        links = {"A": [("ab", bus_ab.path)],
                 "B": [("ab", bus_ab.path), ("bc", bus_bc.path)],
                 "C": [("bc", bus_bc.path)]}
        routers = {k: _mk_router(d, links[k]) for k, d in doms.items()}
        for r in routers.values():
            r.activate(POINT_CLOUD2, topic)
        pub = doms["A"].create_publisher(POINT_CLOUD2, topic, depth=8)
        sub_c = doms["C"].create_subscription(POINT_CLOUD2, topic)
        time.sleep(0.3)
        _publish(pub, 42)
        got = []
        deadline = time.monotonic() + 20
        while not got and time.monotonic() < deadline:
            for r in routers.values():
                r.spin_once(0.01)
            got = sub_c.take()
        assert got, "message never reached C"
        ptr = got[0]
        assert int(np.asarray(ptr.data)[0]) == 42
        assert ptr.hops == 2                       # two bus hops: ab then bc
        assert ptr.src_tag == routers["A"].tag     # origin identity preserved
        assert ptr.src_tag == domain_tag(doms["A"].name)
        ptr.release()
    finally:
        for r in routers.values():
            r.close()
        for d in doms.values():
            d.close()
        bus_ab.stop()
        bus_bc.stop()


# ---------------------------------------------------------------------------
# copy-in abort safety (the loaned-message leak fix)
# ---------------------------------------------------------------------------


def test_copy_in_abort_returns_loan_no_leak():
    """A frame that fails mid-fill (wrong schema) must return the borrowed
    loan's arena blocks and leave the bridge fully operational."""
    bus = Bus().start()
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        br = DomainBridge(dom, bus.path, name="r")
        br.attach(POINT_CLOUD2, "t")
        cli = BusClient(bus.path)
        time.sleep(0.2)
        app = dom.create_subscription(POINT_CLOUD2, "t")
        baseline = dom.arena.live_bytes

        # failure 1: not even a frame (deserialize raises, pre-borrow)
        cli.publish("t", b"\x00\x01junk-not-a-frame")
        # failure 2: a valid frame of the WRONG schema — the loan is
        # borrowed and the fill fails mid-way (the leak path the old
        # Bridge.pump_bus had)
        from repro.core import TOKEN_BATCH
        cli.publish("t", serialize(TOKEN_BATCH.plain()))
        deadline = time.monotonic() + 10
        while br.copy_errors < 2 and time.monotonic() < deadline:
            br.pump_bus(0.05)
        assert br.copy_errors == 2
        assert br.relayed_in == 0
        assert dom.arena.live_bytes == baseline    # loan fully returned

        # the same bridge still relays well-formed frames afterwards
        good = POINT_CLOUD2.plain()
        good.data = np.arange(24, dtype=np.uint8)
        cli.publish("t", serialize(good))
        deadline = time.monotonic() + 10
        while br.relayed_in == 0 and time.monotonic() < deadline:
            br.pump_bus(0.05)
        got = app.take()
        assert len(got) == 1
        assert np.array_equal(np.asarray(got[0].data),
                              np.arange(24, dtype=np.uint8))
        got[0].release()
        cli.close()
        br.close()
    finally:
        dom.close()
        bus.stop()


# ---------------------------------------------------------------------------
# bridge backpressure: park on full ring, executor-multiplexed wakeup
# ---------------------------------------------------------------------------


def test_bridge_backpressure_parks_then_executor_resumes():
    """Copy-ins beyond the ring depth park the bridge (no frame loss, no
    busy-poll); releasing the held refs wakes it through the blocked
    publisher's slot-freed FIFO and everything lands in order."""
    bus = Bus().start()
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        br = DomainBridge(dom, bus.path, name="r", depth=2)
        br.attach(POINT_CLOUD2, "t")
        cli = BusClient(bus.path)
        time.sleep(0.2)
        sub = dom.create_subscription(POINT_CLOUD2, "t")
        held, vals = [], []

        def cb(ptr):
            vals.append(int(np.asarray(ptr.data)[0]))
            held.append(ptr.clone())   # hold the ring slot hostage

        ex = EventExecutor(name="bp")
        ex.add_subscription(sub, cb)
        br.register(ex)

        def send(i):
            m = POINT_CLOUD2.plain()
            m.data = np.full(16, i, np.uint8)
            cli.publish("t", serialize(m))

        # fill the depth-2 ring and take refs on both slots first (a held
        # slot is what blocks; an unreceived one would just be QoS-dropped)
        send(0), send(1)
        ex.spin(until=lambda: len(vals) >= 2, timeout=10)
        # now overflow: the third copy-in must park the bridge, not drop
        send(2), send(3)
        deadline = time.monotonic() + 10
        while br.blocked_publisher is None and time.monotonic() < deadline:
            ex.spin_once(0.05)
        assert br.relayed_in == 2
        assert br.blocked_publisher is not None    # parked, frame retained
        ex.spin(timeout=0.3)                       # no wakeup -> stays parked
        assert br.relayed_in == 2
        # release the hostages: the slot-freed FIFO must wake the bridge
        deadline = time.monotonic() + 10
        while br.relayed_in < 4 and time.monotonic() < deadline:
            for ptr in held:
                ptr.release()
            held.clear()
            ex.spin_once(0.05)
        ex.spin(until=lambda: len(vals) >= 4, timeout=10)  # final dispatch
        for ptr in held:
            ptr.release()
        ex.shutdown()
        assert br.relayed_in == 4
        assert vals == [0, 1, 2, 3]                # order preserved
        assert br.blocked_publisher is None
        cli.close()
        br.close()
    finally:
        dom.close()
        bus.stop()


def test_route_id_spaces_disjoint_and_incarnation_unique():
    """Dedup keys must never collide across id spaces or process restarts:
    adopted-frame ids live above _ADOPTED_ID, origin ids below it, and both
    are salted per incarnation (arena name / random router salt) so a
    restarted publisher or router cannot replay keys already recorded in a
    remote dedup window."""
    from repro.core.routing import (_ADOPTED_ID, _origin_route_seq,
                                    _origin_salt)

    # the origin id space is bounded below _ADOPTED_ID
    assert _origin_route_seq(0xFFFF_FFFF, 0xFFFF_FFFF) < _ADOPTED_ID
    # same ring position, different publisher incarnation (fresh arena
    # name) -> different ids; sibling bridges (same inputs) -> same id
    a = _origin_route_seq(_origin_salt("agnoheap-aaaa", 3, 0), 5)
    b = _origin_route_seq(_origin_salt("agnoheap-bbbb", 3, 0), 5)
    assert a != b
    assert a == _origin_route_seq(_origin_salt("agnoheap-aaaa", 3, 0), 5)
    dom = Domain.create(arena_capacity=4 << 20)
    try:
        r1, r2 = Router(dom), Router(dom)   # e.g. two processes, one domain
        ids = {r1.next_route_seq(), r1.next_route_seq(),
               r2.next_route_seq(), r2.next_route_seq()}
        assert len(ids) == 4                # counters alone would collide
        assert all(i >= _ADOPTED_ID for i in ids)
    finally:
        dom.close()


def test_attach_after_register_is_multiplexed():
    """A topic activated after the bridge is already on the executor loop
    must still relay agnocast -> bus (its wakeup FIFO joins the loop)."""
    bus = Bus().start()
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        br = DomainBridge(dom, bus.path, name="r")
        br.attach(POINT_CLOUD2, "early")
        cli = BusClient(bus.path)
        cli.subscribe("late")
        with EventExecutor(name="late-attach") as ex:
            br.register(ex)
            ex.spin_once(0.05)
            br.attach(POINT_CLOUD2, "late")          # after register()
            pub = dom.create_publisher(POINT_CLOUD2, "late", depth=4)
            time.sleep(0.2)
            _publish(pub, 9)
            ex.spin(until=lambda: br.relayed_out >= 1, timeout=10)
            got = cli.recv(timeout=10)
        assert got is not None and got[0] == "late"
        cli.close()
        br.close()
    finally:
        dom.close()
        bus.stop()


# ---------------------------------------------------------------------------
# no sleep-polling anywhere on the publish/bridge hot paths
# ---------------------------------------------------------------------------


def test_no_sleep_backpressure_on_publish_paths():
    """The former sleep-retry loops are gone.  The actual enforcement
    lives in agnolint (AGNO-HOT-001: no time.sleep on publish hot-path
    modules; AGNO-HOT-002: no queue-full retry coupling in the apps) —
    this test just runs the linter over the real modules so the property
    stays a tier-1 gate and not only a CI-job one."""
    import repro.analysis as analysis

    mods = ["src/repro/core/topic.py", "src/repro/core/routing.py",
            "src/repro/core/executor.py", "src/repro/data/pipeline.py",
            "src/repro/apps/pointcloud.py"]
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rep = analysis.lint_paths([os.path.join(root, m) for m in mods],
                              root=root)
    hot = [f for f in rep.findings if f.rule.startswith("AGNO-HOT")]
    assert hot == [], [str(f) for f in hot]


# ---------------------------------------------------------------------------
# arena pressure (OutOfArenaMemory): bounded retry, counted drop, dedup release
# ---------------------------------------------------------------------------


def _oom_frame(remote, topic, nbytes, route_seq):
    from repro.core import POINT_CLOUD2

    pm = POINT_CLOUD2.plain()
    pm.data = np.zeros(nbytes, np.uint8)
    remote.publish(topic, serialize(pm), origin=1, hops=1,
                   src_tag=777_000, route_seq=route_seq)


def test_bridge_oom_copy_in_recovers_after_one_retry():
    """Arena pressure during copy-in is retried once after a bounded wait:
    when the pressure clears in that window the frame IS delivered (no
    silent drop), and the retry is counted."""
    import threading

    from repro.core import POINT_CLOUD2, Bus, BusClient

    bus = Bus().start()
    dom = Domain.create(arena_capacity=1 << 20)  # small: easy to exhaust
    try:
        br = DomainBridge(dom, bus.path, name="oomr")
        br.attach(POINT_CLOUD2, "oomt")
        remote = BusClient(bus.path)
        time.sleep(0.2)
        hog = dom.arena.alloc(dom.arena.capacity - (192 << 10))

        def releaser():  # free the hog only once the first attempt OOMed
            deadline = time.monotonic() + 5
            while br.oom_retries < 1 and time.monotonic() < deadline:
                time.sleep(0.002)
            dom.arena.free(hog)

        th = threading.Thread(target=releaser)
        th.start()
        _oom_frame(remote, "oomt", 256 << 10, route_seq=41)
        deadline = time.monotonic() + 10
        drops_seen = 0
        while br.relayed_in < 1 and time.monotonic() < deadline:
            br.pump_bus(0.2)
            if br.dropped_oom > drops_seen:
                # a scheduler stall ate the whole retry window: the dedup
                # key was released, so simply offer the frame again
                drops_seen = br.dropped_oom
                _oom_frame(remote, "oomt", 256 << 10, route_seq=41)
        th.join()
        assert br.relayed_in == 1
        assert br.oom_retries >= 1
        assert br.stats()["copy_errors"] == 0
        remote.close()
        br.close()
    finally:
        dom.close()
        bus.stop()


def test_bridge_oom_final_drop_releases_dedup_key():
    """If the retry ALSO hits arena pressure the frame is dropped — but
    counted (dropped_oom, not copy_errors) and its dedup key is released,
    so the same routed message delivered later is not treated as a dup."""
    from repro.core import POINT_CLOUD2, Bus, BusClient

    bus = Bus().start()
    dom = Domain.create(arena_capacity=1 << 20)
    try:
        br = DomainBridge(dom, bus.path, name="oomd")
        br.attach(POINT_CLOUD2, "oomt")
        remote = BusClient(bus.path)
        time.sleep(0.2)
        hog = dom.arena.alloc(dom.arena.capacity - (192 << 10))
        live_before = dom.arena.live_bytes
        _oom_frame(remote, "oomt", 256 << 10, route_seq=42)
        deadline = time.monotonic() + 5
        while br.dropped_oom < 1 and time.monotonic() < deadline:
            br.pump_bus(0.2)
        assert br.dropped_oom == 1 and br.relayed_in == 0
        assert br.copy_errors == 0          # pressure is not "malformed"
        # abort-safe: every block the failed borrows allocated was returned
        assert dom.arena.live_bytes == live_before
        dom.arena.free(hog)
        # same (src_tag, route_seq) again: dedup key was released on the
        # final drop, so this copy must be admitted and delivered
        _oom_frame(remote, "oomt", 256 << 10, route_seq=42)
        deadline = time.monotonic() + 5
        while br.relayed_in < 1 and time.monotonic() < deadline:
            br.pump_bus(0.2)
        assert br.relayed_in == 1 and br.dropped_dups == 0
        remote.close()
        br.close()
    finally:
        dom.close()
        bus.stop()


def test_bridge_parking_is_per_endpoint_no_head_of_line_blocking():
    """A full ring on ONE topic must not stall the bridge's other topics:
    parking is per endpoint (one parked loan + a bounded backlog per
    topic), so topic-B frames keep landing while topic A is parked — and
    A's frames still arrive, in order, once its refs are released."""
    bus = Bus().start()
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        br = DomainBridge(dom, bus.path, name="hol", depth=2)
        br.attach(POINT_CLOUD2, "hol/a")
        br.attach(POINT_CLOUD2, "hol/b", depth=8)  # B must hold all 4 frames
        cli = BusClient(bus.path)
        time.sleep(0.2)
        sub_a = dom.create_subscription(POINT_CLOUD2, "hol/a")
        sub_b = dom.create_subscription(POINT_CLOUD2, "hol/b")

        def send(topic, i):
            m = POINT_CLOUD2.plain()
            m.data = np.full(16, i, np.uint8)
            cli.publish(topic, serialize(m))

        def pump(cond, timeout=10.0):
            deadline = time.monotonic() + timeout
            while not cond() and time.monotonic() < deadline:
                br.pump_bus(0.05)

        # fill A's depth-2 ring and hold refs on both slots
        send("hol/a", 0), send("hol/a", 1)
        pump(lambda: br.relayed_in >= 2)
        held = sub_a.take()
        assert len(held) == 2
        # overflow A: the third copy-in parks ONLY endpoint A...
        send("hol/a", 2), send("hol/a", 3)
        pump(lambda: br.stats()["parked"] >= 1)
        assert br.stats()["parked"] == 1
        assert br.relayed_in == 2
        # ...and B keeps flowing while A is parked (the regression)
        for i in range(4):
            send("hol/b", 10 + i)
        pump(lambda: br.relayed_in >= 6)
        ptrs_b = sub_b.take()
        got_b = [int(np.asarray(p.data)[0]) for p in ptrs_b]
        for p in ptrs_b:
            p.release()
        assert got_b == [10, 11, 12, 13]
        assert br.stats()["parked"] == 1          # A still parked throughout
        # release A's hostages: parked loan + backlog drain in order
        for ptr in held:
            ptr.release()
        pump(lambda: br.relayed_in >= 8)
        got_a = [int(np.asarray(p.data)[0]) for p in sub_a.take()]
        assert got_a == [2, 3]                    # FIFO order preserved
        assert br.stats()["parked"] == 0
        assert br.stats()["dropped_backlog"] == 0
        cli.close()
        br.close()
    finally:
        dom.close()
        bus.stop()
