import os
import sys

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_SRC, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)

# ``hypothesis`` is a test dependency (requirements-test.txt) but hermetic
# containers may lack it; without this shim six modules error at collection.
# Prefer the real package; otherwise install the deterministic fallback so
# the property tests still *run* (boundary probes + seeded random examples)
# instead of degrading the whole module to a collection error.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
