import os
import sys

_HERE = os.path.dirname(__file__)
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_SRC, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)
