"""Cross-host data plane: TZC-style control/data split.

Covers the attach-by-name plane (ref + copy modes, pin/ack lifecycle,
NACK → serialized-fallback exactly-once), the registry pin/lease
semantics, the zero-assembly serialize/deserialize paths, and the bus's
bounded-backlog fan-out (head-of-line fix).
"""

import time

import numpy as np
import pytest

from repro.core import (
    POINT_CLOUD2,
    Bus,
    BusClient,
    Domain,
    DomainBridge,
    deserialize,
    serialize,
    serialize_parts,
)
from repro.core.messages import PlainMessage


def _publish(pub, value, n=64):
    m = pub.borrow_loaded_message()
    m.data.extend(np.full(n, value, np.uint8))
    m.set("stamp", float(value))
    pub.reclaim()
    pub.publish_blocking(m, timeout=10.0)


def _pump_until(pred, *bridges, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        for br in bridges:
            br.pump_agnocast()
            br.pump_bus(0.01)
        if pred():
            return True
    return False


# ---------------------------------------------------------------------------
# serialize_parts / deserialize(copy=False)
# ---------------------------------------------------------------------------


def test_serialize_parts_wire_identical():
    """header + joined views must be byte-identical to serialize(): the
    scatter-gather send path needs zero receiver-side changes."""
    m = PlainMessage(POINT_CLOUD2)
    m.data = np.arange(1000, dtype=np.uint8).reshape(-1)[:1000] % 251
    m.stamp = 42.5
    header, views = serialize_parts(m)
    assert header + b"".join(bytes(v) for v in views) == serialize(m)


def test_deserialize_copy_false_returns_views():
    m = PlainMessage(POINT_CLOUD2)
    m.data = (np.arange(4096) % 256).astype(np.uint8)
    m.stamp = 1.0
    buf = serialize(m)
    fields = deserialize(buf, copy=False)
    np.testing.assert_array_equal(fields["data"],
                                  (np.arange(4096) % 256).astype(np.uint8))
    # zero-copy: the array is a read-only view over the caller's buffer
    assert not fields["data"].flags.writeable
    assert fields["data"].base is not None
    # copy=True (default) stays a private, writable copy
    owned = deserialize(buf)
    owned["data"][0] = 7  # must not raise
    assert owned["data"].flags.writeable


# ---------------------------------------------------------------------------
# registry pins (cross-bridge lease on loaned entries)
# ---------------------------------------------------------------------------


def test_pin_blocks_reclaim_until_unpin():
    with Domain.create(arena_capacity=8 << 20) as dom:
        pub = dom.create_publisher(POINT_CLOUD2, "t/pin", depth=4)
        sub = dom.create_subscription(POINT_CLOUD2, "t/pin")
        _publish(pub, 1)
        ptr = sub.take()[0]
        seq = ptr.seq
        assert dom.registry.pin(pub.tidx, pub.pidx, seq, 10.0, gen=pub.tgen)
        ptr.release()
        # fully released, but the pin holds the entry for the remote reader
        assert dom.registry.reclaimable(pub.tidx, pub.pidx) == []
        dom.registry.unpin(pub.tidx, pub.pidx, seq, gen=pub.tgen)
        assert dom.registry.reclaimable(pub.tidx, pub.pidx) == [seq]


def test_pin_lease_expiry_reclaims():
    """A crashed pinner cannot wedge the ring: past the lease deadline the
    owner reclaims as if the pin were gone."""
    with Domain.create(arena_capacity=8 << 20) as dom:
        pub = dom.create_publisher(POINT_CLOUD2, "t/lease", depth=4)
        sub = dom.create_subscription(POINT_CLOUD2, "t/lease")
        _publish(pub, 1)
        ptr = sub.take()[0]
        seq = ptr.seq
        assert dom.registry.pin(pub.tidx, pub.pidx, seq, 0.05, gen=pub.tgen)
        ptr.release()
        assert dom.registry.reclaimable(pub.tidx, pub.pidx) == []
        time.sleep(0.08)
        assert dom.registry.reclaimable(pub.tidx, pub.pidx) == [seq]


def test_pin_missing_entry_returns_false():
    with Domain.create(arena_capacity=8 << 20) as dom:
        pub = dom.create_publisher(POINT_CLOUD2, "t/none", depth=4)
        assert not dom.registry.pin(pub.tidx, pub.pidx, 99, 1.0, gen=pub.tgen)


# ---------------------------------------------------------------------------
# attach-by-name relay (same-host control/data split)
# ---------------------------------------------------------------------------


def _mk_pair(bus, topic, **kw):
    domA = Domain.create(arena_capacity=16 << 20)
    domB = Domain.create(arena_capacity=16 << 20)
    brA = DomainBridge(domA, bus.path, name="A", **kw)
    brB = DomainBridge(domB, bus.path, name="B", **kw)
    brA.attach(POINT_CLOUD2, topic)
    brB.attach(POINT_CLOUD2, topic)
    return domA, domB, brA, brB


@pytest.mark.parametrize("mode", ["ref", "copy"])
def test_attach_relay_delivers(mode):
    """data_plane="attach": only the control frame transits the bus; the
    receiver reads the fields out of the source arena (ref: republishes the
    descriptor verbatim — subscribers see the *source* arena)."""
    topic = "t/attach"
    bus = Bus().start()
    domA, domB, brA, brB = _mk_pair(bus, topic, data_plane="attach",
                                    attach_mode=mode, pin_lease_s=5.0)
    try:
        pub = domA.create_publisher(POINT_CLOUD2, topic, depth=8)
        sub = domB.create_subscription(POINT_CLOUD2, topic)
        time.sleep(0.2)  # SUB frames land
        got = []
        for i in range(3):
            _publish(pub, i + 1)
        assert _pump_until(lambda: len(got) >= 3 or _take(sub, got) >= 3,
                           brA, brB)
        assert [v for v, _ in got] == [1, 2, 3]
        if mode == "ref":
            # true zero-copy: the delivered views live in A's arena
            assert all(a == domA.arena.name for _, a in got)
        assert brA.attach_out == 3
        assert brB.attach_in == 3
        assert brA.attach_fallbacks == 0
        # acks settle the pins (ref: after release+reclaim sweep)
        assert _pump_until(lambda: not brA._awaiting, brA, brB, timeout=5.0)
    finally:
        brA.close()
        brB.close()
        domA.close()
        domB.close()
        bus.stop()


def _take(sub, got):
    for ptr in sub.take():
        got.append((int(np.asarray(ptr.data)[0]), ptr.msg.arena_name))
        ptr.release()
    return len(got)


def test_attach_fanout_zero_settles_without_fallback():
    """A control frame with no remote subscriber behaves like conventional
    pub/sub with no subscriber: the pin is dropped at the FANOUT receipt,
    no fallback, no timeout."""
    topic = "t/nobody"
    bus = Bus().start()
    dom = Domain.create(arena_capacity=8 << 20)
    br = DomainBridge(dom, bus.path, name="A", data_plane="attach")
    br.attach(POINT_CLOUD2, topic)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, topic, depth=4)
        _publish(pub, 9)
        br.pump_agnocast()
        assert len(br._awaiting) == 1
        deadline = time.monotonic() + 5
        while br._awaiting and time.monotonic() < deadline:
            br.pump_bus(0.05)
        assert not br._awaiting
        assert br.attach_fallbacks == 0
        assert br.ack_timeouts == 0
    finally:
        br.close()
        dom.close()
        bus.stop()


def test_attach_failure_nacks_and_falls_back_exactly_once():
    """Satellite: a control frame whose data read fails (source arena
    unlinked before the receiver ever attached it) must forget() its dedup
    key and be re-delivered over the serialized path exactly once — never
    dropped, never duplicated."""
    topic = "t/unlink"
    bus = Bus().start()
    domA, domB, brA, brB = _mk_pair(bus, topic, data_plane="attach",
                                    attach_mode="copy", pin_lease_s=5.0)
    try:
        pub = domA.create_publisher(POINT_CLOUD2, topic, depth=8)
        sub = domB.create_subscription(POINT_CLOUD2, topic)
        time.sleep(0.2)
        _publish(pub, 7)
        brA.pump_agnocast()  # CTRL sent, pin held
        assert len(brA._awaiting) == 1
        # unlink the source arena NAME: A's own mapping (and the pinned
        # payload) stays valid, but attach-by-name on B now fails
        domA.arena.unlink()
        brB.pump_bus(0.5)  # CTRL arrives -> attach fails -> NACK + forget
        assert brB.attach_nacks == 1
        assert brB.relayed_in == 0
        brA.pump_bus(0.5)  # receipt + NACK -> serialized fallback, unpin
        assert brA.attach_fallbacks == 1
        assert not brA._awaiting
        got = []
        assert _pump_until(lambda: _take(sub, got) >= 1, brA, brB)
        assert [v for v, _ in got] == [7]
        # settle: the fallback must not deliver twice
        for _ in range(5):
            brA.pump_bus(0.02)
            brB.pump_bus(0.02)
        _take(sub, got)
        assert [v for v, _ in got] == [7]
    finally:
        brA.close()
        brB.close()
        domA.close()
        domB.close()
        bus.stop()


def test_ack_timeout_falls_back_when_receiver_dies():
    """Receiver bridge killed after the CTRL was sent: the sender's ack
    timeout degrades the message to a serialized re-send (picked up by a
    replacement bridge) instead of leaking the pin."""
    topic = "t/dead"
    bus = Bus().start()
    domA, domB, brA, brB = _mk_pair(bus, topic, data_plane="attach",
                                    attach_mode="copy", pin_lease_s=0.4)
    try:
        pub = domA.create_publisher(POINT_CLOUD2, topic, depth=8)
        time.sleep(0.2)
        _publish(pub, 3)
        brA.pump_agnocast()
        assert len(brA._awaiting) == 1
        time.sleep(0.2)  # CTRL fan-out reaches brB's socket (fanout = 1)
        brB.close()  # dies without ever reading the CTRL
        deadline = time.monotonic() + 5
        while brA._awaiting and time.monotonic() < deadline:
            brA.pump_bus(0.05)
        assert not brA._awaiting
        assert brA.attach_fallbacks == 1
        # the pin is gone: the ring slot becomes reclaimable again
        sub = domA.create_subscription(POINT_CLOUD2, topic)
        assert pub.reclaim() >= 0  # no wedge; smoke that reclaim runs
    finally:
        brA.close()
        domA.close()
        domB.close()
        bus.stop()


# ---------------------------------------------------------------------------
# bus head-of-line fix (bounded backlog fan-out)
# ---------------------------------------------------------------------------


def test_bus_slow_subscriber_does_not_block_others():
    """One stalled subscriber must not stall the bus: its backlog is shed
    (counted) while a draining subscriber receives everything."""
    bus = Bus(max_backlog=1 << 20).start()
    slow = BusClient(bus.path)
    fast = BusClient(bus.path)
    sender = BusClient(bus.path)
    try:
        slow.subscribe("t/hol")
        fast.subscribe("t/hol")
        time.sleep(0.2)
        payload = b"\x5a" * (512 << 10)  # 512 KiB frames vs 1 MiB backlog
        got = 0
        for i in range(12):
            sender.publish("t/hol", payload, route_seq=i)
            fr = fast.recv_frame(5.0)  # drain fast so only slow backs up
            assert fr is not None and fr.payload == payload
            got += 1
        assert got == 12
        deadline = time.monotonic() + 5
        while bus.dropped_backlog == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert bus.dropped_backlog > 0  # slow's overflow was shed, not fatal
    finally:
        slow.close()
        fast.close()
        sender.close()
        bus.stop()
