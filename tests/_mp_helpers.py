"""Child-process entry points for multiprocess tests (spawn-safe)."""

import os
import signal
import time


def echo_subscriber(reg_name, topic, q, n_expected):
    from repro.core import POINT_CLOUD2, Domain

    dom = Domain.join(reg_name, publisher=False)
    sub = dom.create_subscription(POINT_CLOUD2, topic)
    q.put("ready")
    n = 0
    t0 = time.time()
    while n < n_expected and time.time() - t0 < 30:
        if sub.wait(0.5):
            for ptr in sub.take():
                q.put(int(ptr.data.sum()))
                ptr.release()
                n += 1
    q.put("done")


def crash_holding_subscriber(reg_name, topic, q):
    from repro.core import POINT_CLOUD2, Domain

    dom = Domain.join(reg_name, publisher=False)
    sub = dom.create_subscription(POINT_CLOUD2, topic)
    q.put("ready")
    t0 = time.time()
    while time.time() - t0 < 30:
        if sub.wait(0.5):
            if sub.take():  # take and DIE while holding the reference
                q.put("holding")
                time.sleep(0.5)  # let the queue feeder flush
                os.kill(os.getpid(), signal.SIGKILL)


def remote_publisher(reg_name, topic, q, payload_sizes):
    import numpy as np

    from repro.core import POINT_CLOUD2, Domain

    dom = Domain.join(reg_name, arena_capacity=32 << 20)
    pub = dom.create_publisher(POINT_CLOUD2, topic, depth=16)
    q.put("ready")
    q.get(timeout=30)  # wait for go
    for i, n in enumerate(payload_sizes):
        m = pub.borrow_loaded_message()
        m.data.extend(np.full(n, i % 251, np.uint8))
        pub.publish(m)
    # stay alive until the parent confirms receipt (owner holds the arena)
    q.get(timeout=30)


def crash_publisher(reg_name):
    """Publish once, then die without any cleanup (no atexit, no close)."""
    import numpy as np

    from repro.core import POINT_CLOUD2, Domain

    d = Domain.join(reg_name, arena_capacity=8 << 20)
    p = d.create_publisher(POINT_CLOUD2, "t", depth=4)
    m = p.borrow_loaded_message()
    m.data.extend(np.ones(1000, np.uint8))
    p.publish(m)
    os._exit(1)


def executor_subscriber(reg_name, topics, q, n_expected):
    """Event-driven fan-in consumer: ONE EventExecutor multiplexing every
    topic's wakeup FIFO in a child process (cross-process wakeup path)."""
    from repro.core import POINT_CLOUD2, Domain, EventExecutor

    dom = Domain.join(reg_name, publisher=False)
    ex = EventExecutor(name="mp-executor")
    got = []

    def callback_for(topic):
        def cb(ptr):
            rec = (topic, int(ptr.seq), int(ptr.data.sum()))
            got.append(rec)
            q.put(rec)

        return cb

    for t in topics:
        ex.add_subscription(dom.create_subscription(POINT_CLOUD2, t),
                            callback_for(t))
    q.put("ready")
    ex.spin(until=lambda: len(got) >= n_expected, timeout=30)
    ex.shutdown()
    q.put("done")
    dom.close()


def holding_releaser(reg_name, topic, q_out, q_in):
    """Take-and-hold subscriber for backpressure tests: holds every ref it
    takes until told to release (the cross-process slot-freed-FIFO path)."""
    from repro.core import POINT_CLOUD2, Domain

    dom = Domain.join(reg_name, publisher=False)
    sub = dom.create_subscription(POINT_CLOUD2, topic)
    q_out.put("ready")
    held = []
    t0 = time.time()
    while len(held) < 2 and time.time() - t0 < 30:
        if sub.wait(0.5):
            held.extend(sub.take())
    q_out.put("holding")
    assert q_in.get(timeout=30) == "release"
    for ptr in held:
        ptr.release()
    q_out.put("released")
    assert q_in.get(timeout=30) == "done"  # parent confirms before teardown
    dom.close()


def bridge_runner(reg_name, bus_path, topic, q, run_s=10.0):
    from repro.core import POINT_CLOUD2, Bridge, Domain

    dom = Domain.join(reg_name, arena_capacity=16 << 20)
    br = Bridge(dom, bus_path, POINT_CLOUD2, topic)
    q.put("ready")
    t0 = time.time()
    while time.time() - t0 < run_s:
        br.spin_once(0.05)
    q.put(("counts", br.relayed_out, br.relayed_in))
    time.sleep(0.5)


def crash_mid_mutation(reg_name, topic, q, hold_s=1.0):
    """Die mid-mutation on ``topic`` WHILE HOLDING its per-topic lock: a
    PENDING journal slot + torn row are left behind, and the kernel must
    release the flock on SIGKILL.  The parent proves (a) other topics'
    traffic proceeds during the hold, (b) the next acquirer of THIS topic
    rolls the torn write back."""
    from repro.core.registry import _J_PENDING, Registry

    reg = Registry.attach(reg_name)
    t = reg.topic_index(topic, create=False)
    lock = reg._topic_flock(t)
    lock.__enter__()            # hold topic t's lock until death
    j = reg._journal[t]
    j["pid"] = os.getpid()
    j["tidx"], j["pidx"], j["slot"] = t, 0, 1
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = reg.entries[t, 0, 1].tobytes()
    j["state"] = _J_PENDING
    reg.entries[t, 0, 1]["desc_off"] = 31337   # the torn write
    q.put("holding")
    time.sleep(hold_s)          # parent drives topic B traffic meanwhile
    os.kill(os.getpid(), signal.SIGKILL)


def hammer_publish(reg_name, topic, q):
    """Hammer one topic's full hot path (publish/take/release) until
    killed: the parent SIGKILLs this process at a random point, likely
    mid-critical-section, and then proves the seqlock plane converges."""
    from repro.core.registry import Registry

    reg = Registry.attach(reg_name)
    t = reg.topic_index(topic)
    p = reg.add_publisher(t, os.getpid(), "hammer-arena", depth=8)
    s = reg.add_subscriber(t, os.getpid())
    q.put("running")
    i = 0
    while True:
        i += 1
        try:
            seq, _ = reg.publish(t, p, i, 1)
        except Exception:
            continue
        for e in reg.take(t, s):
            reg.release(t, p, s, e.seq)
        reg.reclaimable(t, p)
