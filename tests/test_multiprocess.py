"""Cross-process integration: true zero-copy IPC, crash cleanup, bridge."""

import multiprocessing as mp
import time

import numpy as np
import pytest

import _mp_helpers as H
from repro.core import (
    POINT_CLOUD2,
    Bus,
    BusClient,
    Domain,
    deserialize,
    serialize,
)

pytestmark = pytest.mark.timeout if hasattr(pytest.mark, "__timeout__") else []


@pytest.fixture(scope="module")
def ctx():
    return mp.get_context("spawn")


def test_cross_process_delivery(ctx):
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        q = ctx.Queue()
        p = ctx.Process(target=H.echo_subscriber, args=(dom.name, "pc", q, 5))
        p.start()
        assert q.get(timeout=15) == "ready"
        for i in range(5):
            m = pub.borrow_loaded_message()
            m.data.extend(np.full(100, i, np.uint8))
            pub.publish(m)
            time.sleep(0.02)
        sums = [q.get(timeout=15) for _ in range(5)]
        assert sums == [0, 100, 200, 300, 400]
        assert q.get(timeout=15) == "done"
        p.join(timeout=10)
        dom.sweep()
        pub.reclaim()
        assert dom.arena.live_bytes == 0
    finally:
        dom.close()


def test_crashed_subscriber_references_released(ctx):
    """The kernel-module exit-hook analogue: a subscriber SIGKILLed while
    holding a message must not leak the payload (§IV-B/§IV-C)."""
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        q = ctx.Queue()
        p = ctx.Process(target=H.crash_holding_subscriber, args=(dom.name, "pc", q))
        p.start()
        assert q.get(timeout=15) == "ready"
        m = pub.borrow_loaded_message()
        m.data.extend(np.zeros(4096, np.uint8))
        pub.publish(m)
        assert q.get(timeout=15) == "holding"
        p.join(timeout=10)
        time.sleep(0.2)
        rep = dom.sweep()
        assert rep["dead_subs"] >= 1
        assert pub.reclaim() == 1
        assert dom.arena.live_bytes == 0
    finally:
        dom.close()


def test_subscribe_to_remote_publisher_zero_copy(ctx):
    """Subscriber in THIS process reads payload bytes directly out of the
    remote publisher's arena (no serialization anywhere)."""
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        sub = dom.create_subscription(POINT_CLOUD2, "pc")
        q = ctx.Queue()
        sizes = [10, 100_000, 1_000_000]
        p = ctx.Process(target=H.remote_publisher, args=(dom.name, "pc", q, sizes))
        p.start()
        assert q.get(timeout=15) == "ready"
        q.put("go")
        got = []
        t0 = time.time()
        while len(got) < len(sizes) and time.time() - t0 < 20:
            if sub.wait(0.5):
                got.extend(sub.take())
        assert [g.data.shape[0] for g in got] == sizes
        for i, g in enumerate(got):
            assert np.all(g.data == i % 251)
            g.release()
        q.put("done")
        p.join(timeout=10)
    finally:
        dom.close()


def test_bridge_relays_both_directions(ctx):
    bus = Bus().start()
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        q = ctx.Queue()
        bp = ctx.Process(target=H.bridge_runner, args=(dom.name, bus.path, "pc", q, 10.0))
        bp.start()
        assert q.get(timeout=15) == "ready"
        time.sleep(0.3)

        # Route 1: agnocast publisher -> bridge -> conventional subscriber
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        rosish = BusClient(bus.path)
        rosish.subscribe("pc")
        time.sleep(0.2)
        m = pub.borrow_loaded_message()
        m.data.extend(np.arange(64, dtype=np.uint8))
        pub.publish(m)
        got = rosish.recv(timeout=10)
        assert got is not None
        _, origin, payload = got
        assert origin == 1  # bridge-tagged
        assert np.array_equal(deserialize(payload)["data"], np.arange(64, dtype=np.uint8))

        # Route 2: conventional publisher -> bridge -> agnocast subscriber
        sub = dom.create_subscription(POINT_CLOUD2, "pc")
        pm = POINT_CLOUD2.plain()
        pm.data = np.full(32, 7, np.uint8)
        rosish.publish("pc", serialize(pm), origin=0)
        msgs = []
        t0 = time.time()
        while not msgs and time.time() - t0 < 10:
            sub.wait(0.5)
            msgs = sub.take()
        assert msgs and np.array_equal(msgs[0].data, np.full(32, 7, np.uint8))
        for x in msgs:
            x.release()

        counts = q.get(timeout=15)
        assert counts[0] == "counts" and counts[1] >= 1 and counts[2] >= 1
        bp.join(timeout=10)
    finally:
        dom.close()
        bus.stop()
