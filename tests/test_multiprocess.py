"""Cross-process integration: true zero-copy IPC, crash cleanup, bridge."""

import multiprocessing as mp
import time

import numpy as np
import pytest

import _mp_helpers as H
from repro.core import (
    POINT_CLOUD2,
    Bus,
    BusClient,
    Domain,
    deserialize,
    serialize,
)

pytestmark = pytest.mark.timeout if hasattr(pytest.mark, "__timeout__") else []


@pytest.fixture(scope="module")
def ctx():
    return mp.get_context("spawn")


def test_cross_process_delivery(ctx):
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        q = ctx.Queue()
        p = ctx.Process(target=H.echo_subscriber, args=(dom.name, "pc", q, 5))
        p.start()
        assert q.get(timeout=15) == "ready"
        for i in range(5):
            m = pub.borrow_loaded_message()
            m.data.extend(np.full(100, i, np.uint8))
            pub.publish(m)
            time.sleep(0.02)
        sums = [q.get(timeout=15) for _ in range(5)]
        assert sums == [0, 100, 200, 300, 400]
        assert q.get(timeout=15) == "done"
        p.join(timeout=10)
        dom.sweep()
        pub.reclaim()
        assert dom.arena.live_bytes == 0
    finally:
        dom.close()


def test_crashed_subscriber_references_released(ctx):
    """The kernel-module exit-hook analogue: a subscriber SIGKILLed while
    holding a message must not leak the payload (§IV-B/§IV-C)."""
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        q = ctx.Queue()
        p = ctx.Process(target=H.crash_holding_subscriber, args=(dom.name, "pc", q))
        p.start()
        assert q.get(timeout=15) == "ready"
        m = pub.borrow_loaded_message()
        m.data.extend(np.zeros(4096, np.uint8))
        pub.publish(m)
        assert q.get(timeout=15) == "holding"
        p.join(timeout=10)
        time.sleep(0.2)
        rep = dom.sweep()
        assert rep["dead_subs"] >= 1
        assert pub.reclaim() == 1
        assert dom.arena.live_bytes == 0
    finally:
        dom.close()


def test_subscribe_to_remote_publisher_zero_copy(ctx):
    """Subscriber in THIS process reads payload bytes directly out of the
    remote publisher's arena (no serialization anywhere)."""
    dom = Domain.create(arena_capacity=8 << 20)
    try:
        sub = dom.create_subscription(POINT_CLOUD2, "pc")
        q = ctx.Queue()
        sizes = [10, 100_000, 1_000_000]
        p = ctx.Process(target=H.remote_publisher, args=(dom.name, "pc", q, sizes))
        p.start()
        assert q.get(timeout=15) == "ready"
        q.put("go")
        got = []
        t0 = time.time()
        while len(got) < len(sizes) and time.time() - t0 < 20:
            if sub.wait(0.5):
                got.extend(sub.take())
        assert [g.data.shape[0] for g in got] == sizes
        for i, g in enumerate(got):
            assert np.all(g.data == i % 251)
            g.release()
        q.put("done")
        p.join(timeout=10)
    finally:
        dom.close()


def test_bridge_relays_both_directions(ctx):
    bus = Bus().start()
    dom = Domain.create(arena_capacity=16 << 20)
    try:
        q = ctx.Queue()
        bp = ctx.Process(target=H.bridge_runner, args=(dom.name, bus.path, "pc", q, 10.0))
        bp.start()
        assert q.get(timeout=15) == "ready"
        time.sleep(0.3)

        # Route 1: agnocast publisher -> bridge -> conventional subscriber
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=8)
        rosish = BusClient(bus.path)
        rosish.subscribe("pc")
        time.sleep(0.2)
        m = pub.borrow_loaded_message()
        m.data.extend(np.arange(64, dtype=np.uint8))
        pub.publish(m)
        got = rosish.recv(timeout=10)
        assert got is not None
        _, origin, payload = got
        assert origin == 1  # bridge-tagged
        assert np.array_equal(deserialize(payload)["data"], np.arange(64, dtype=np.uint8))

        # Route 2: conventional publisher -> bridge -> agnocast subscriber
        sub = dom.create_subscription(POINT_CLOUD2, "pc")
        pm = POINT_CLOUD2.plain()
        pm.data = np.full(32, 7, np.uint8)
        rosish.publish("pc", serialize(pm), origin=0)
        msgs = []
        t0 = time.time()
        while not msgs and time.time() - t0 < 10:
            sub.wait(0.5)
            msgs = sub.take()
        assert msgs and np.array_equal(msgs[0].data, np.full(32, 7, np.uint8))
        for x in msgs:
            x.release()

        counts = q.get(timeout=15)
        assert counts[0] == "counts" and counts[1] >= 1 and counts[2] >= 1
        bp.join(timeout=10)
    finally:
        dom.close()
        bus.stop()


def test_dead_writer_recovered_per_topic_while_others_flow(ctx):
    """Sharded metadata plane (§IV-B per topic): a writer SIGKILLed
    mid-mutation on topic A — while *holding A's lock* — must (a) not stall
    topic B's traffic during the hold (disjoint locks), and (b) be rolled
    back by the next acquirer of A, not by B's acquirers."""
    from repro.core.registry import _J_CLEAN, _J_PENDING, Registry

    reg = Registry.create()
    try:
        import os as _os

        ta = reg.topic_index("A")
        tb = reg.topic_index("B")
        pa = reg.add_publisher(ta, _os.getpid(), "arena-a", depth=4)
        pb = reg.add_publisher(tb, _os.getpid(), "arena-b", depth=4)
        sb = reg.add_subscriber(tb, _os.getpid())
        reg.publish(ta, pa, 7, 1)                    # seq 1 -> slot 1
        q = ctx.Queue()
        child = ctx.Process(target=H.crash_mid_mutation,
                            args=(reg.name, "A", q), kwargs={"hold_s": 1.0})
        child.start()
        assert q.get(timeout=20) == "holding"
        # (a) B's plane is live while A's lock is held by the dying writer
        t0 = time.monotonic()
        seq, _ = reg.publish(tb, pb, 11, 1)
        got = reg.take(tb, sb)
        reg.release(tb, pb, sb, seq)
        b_elapsed = time.monotonic() - t0
        assert [e.seq for e in got] == [seq]
        assert b_elapsed < 0.5, f"B ops stalled {b_elapsed:.2f}s on A's lock"
        child.join(timeout=20)
        assert child.exitcode == -9                 # SIGKILLed mid-mutation
        # B traffic does NOT recover A (journal slots are per topic)...
        reg.publish(tb, pb, 12, 1)
        assert int(reg._journal[ta]["state"]) == _J_PENDING
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == 31337
        # ...the next acquirer of A does: torn write rolled back, WAL clean
        sa = reg.add_subscriber(ta, _os.getpid())
        assert int(reg._journal[ta]["state"]) == _J_CLEAN
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == 7
        assert [e.seq for e in reg.take(ta, sa)] == []  # snapshot semantics
    finally:
        reg.close()
        reg.unlink()


def test_sigkill_mid_publish_converges_lock_free_reads(ctx):
    """SIGKILL a child mid-hammer on the v4 hot path (likely inside a
    critical section: wseq odd, journal PENDING, release bytes pending).
    Lock-free readers must fall back and the next lock holder must repair
    parity + roll the journal back; then traffic flows normally."""
    import os as _os
    import signal as _signal

    from repro.core.registry import _J_CLEAN, Registry

    reg = Registry.create()
    try:
        q = ctx.Queue()
        child = ctx.Process(target=H.hammer_publish,
                            args=(reg.name, "hot", q))
        child.start()
        assert q.get(timeout=20) == "running"
        time.sleep(0.3)                       # mid-flight, arbitrary point
        _os.kill(child.pid, _signal.SIGKILL)
        child.join(timeout=10)

        t = reg.topic_index("hot")
        # lock-free read first: may hit odd parity -> bounded retries ->
        # locked fallback whose recovery repairs the row
        assert isinstance(reg.can_publish(t, 0), bool)
        reg.reclaimable(t, 0)                 # locked op: rollback runs
        assert int(reg._journal[t]["state"]) == _J_CLEAN
        assert int(reg.topics[t]["wseq"]) % 2 == 0
        reg.sweep()                           # reap the dead participant

        s = reg.add_subscriber(t, _os.getpid())
        p = reg.add_publisher(t, _os.getpid(), "after-arena", depth=4)
        seq, _ = reg.publish(t, p, 5, 1)
        got = reg.take(t, s)
        assert [e.seq for e in got] == [seq]
        reg.release(t, p, s, seq)
        assert reg.reclaimable(t, p) == [seq]
    finally:
        reg.close()
        reg.unlink()
