"""§Perf B1 correctness: parallel prefill == sequential decode replay."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import xlstm_model as xm


def _cfg():
    return get_smoke_config("xlstm-1.3b")


def test_parallel_prefill_matches_sequential_replay():
    cfg = _cfg()
    params = xm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)

    logits_p, cache_p = xm.prefill(params, tokens, cfg)
    logits_s, cache_s = xm.prefill_sequential(params, tokens, cfg)

    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_s, np.float32),
                               rtol=2e-2, atol=2e-2)
    for key in ("C", "n", "m"):
        a = np.asarray(cache_p["mlstm"][key], np.float32)
        b = np.asarray(cache_s["mlstm"][key], np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2, err_msg=key)
    np.testing.assert_allclose(
        np.asarray(cache_p["mlstm"]["conv"], np.float32),
        np.asarray(cache_s["mlstm"]["conv"], np.float32),
        rtol=2e-2, atol=2e-2)
    for key in ("h", "c", "n"):
        np.testing.assert_allclose(
            np.asarray(cache_p["slstm"][key], np.float32),
            np.asarray(cache_s["slstm"][key], np.float32),
            rtol=2e-2, atol=2e-2, err_msg=key)
    assert int(cache_p["len"][0]) == int(cache_s["len"][0]) == 24


def test_decode_continues_identically_from_both_prefills():
    cfg = _cfg()
    params = xm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)

    _, cache_p = xm.prefill(params, tokens, cfg)
    _, cache_s = xm.prefill_sequential(params, tokens, cfg)
    lp, _ = xm.decode_step(params, cache_p, nxt, cfg)
    ls, _ = xm.decode_step(params, cache_s, nxt, cfg)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ls, np.float32),
                               rtol=2e-2, atol=2e-2)
