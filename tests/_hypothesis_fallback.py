"""Deterministic mini-``hypothesis`` used when the real package is absent.

The test suite property-tests the metadata plane (arena, registry, pubsub,
packing, kernels) with ``hypothesis``.  That package is a *test* dependency
(see ``requirements-test.txt``) and may be missing in hermetic containers;
without a shim, six test modules fail at **collection** and take the whole
tier-1 run down with them.

Rather than degrading those modules to skips, this module implements the
small strategy subset the suite actually uses (``integers``, ``floats``,
``booleans``, ``just``, ``sampled_from``, ``one_of``, ``lists``,
``tuples``) with a deterministic example generator:

* example 0 draws every strategy at its minimum, example 1 at its maximum
  (the boundary probes real hypothesis is valued for);
* the remaining examples are pseudo-random, seeded from the test's
  qualified name — stable across runs and processes (no shrinking, but a
  printed falsifying example on failure).

``conftest.py`` installs this as ``sys.modules["hypothesis"]`` only when
the real package cannot be imported; with hypothesis installed this file
is inert.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__all__ = ["install", "given", "settings", "assume"]

DEFAULT_MAX_EXAMPLES = 20


class Unsatisfied(Exception):
    """Raised by ``assume(False)`` / failed ``.filter``: discard the example."""


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: random.Random, mode: str | None):
        return self._draw_fn(rng, mode)

    def map(self, f):
        return SearchStrategy(lambda rng, mode: f(self._draw_fn(rng, mode)))

    def filter(self, pred):
        def draw(rng, mode):
            for _ in range(100):
                v = self._draw_fn(rng, mode)
                if pred(v):
                    return v
            raise Unsatisfied("filter predicate never satisfied")

        return SearchStrategy(draw)


def integers(min_value=-(2**31), max_value=2**31 - 1) -> SearchStrategy:
    def draw(rng, mode):
        if mode == "min":
            return int(min_value)
        if mode == "max":
            return int(max_value)
        return rng.randint(int(min_value), int(max_value))

    return SearchStrategy(draw)


def floats(min_value=-1e9, max_value=1e9, **_kw) -> SearchStrategy:
    def draw(rng, mode):
        if mode == "min":
            return float(min_value)
        if mode == "max":
            return float(max_value)
        return rng.uniform(float(min_value), float(max_value))

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(
        lambda rng, mode: {"min": False, "max": True}.get(mode, rng.random() < 0.5))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng, mode: value)


def none() -> SearchStrategy:
    return just(None)


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from of empty sequence")

    def draw(rng, mode):
        if mode == "min":
            return seq[0]
        if mode == "max":
            return seq[-1]
        return seq[rng.randrange(len(seq))]

    return SearchStrategy(draw)


def one_of(*strategies_) -> SearchStrategy:
    if len(strategies_) == 1 and isinstance(strategies_[0], (list, tuple)):
        strategies_ = tuple(strategies_[0])

    def draw(rng, mode):
        if mode == "min":
            return strategies_[0].draw(rng, mode)
        if mode == "max":
            return strategies_[-1].draw(rng, mode)
        return strategies_[rng.randrange(len(strategies_))].draw(rng, mode)

    return SearchStrategy(draw)


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int | None = None) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng, mode):
        if mode == "min":
            n = min_size
        elif mode == "max":
            n = hi
        else:
            n = rng.randint(min_size, hi)
        return [elements.draw(rng, mode) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*strategies_) -> SearchStrategy:
    return SearchStrategy(
        lambda rng, mode: tuple(s.draw(rng, mode) for s in strategies_))


def builds(target, *args, **kwargs) -> SearchStrategy:
    return SearchStrategy(
        lambda rng, mode: target(*(a.draw(rng, mode) for a in args),
                                 **{k: v.draw(rng, mode)
                                    for k, v in kwargs.items()}))


def assume(condition) -> bool:
    if not condition:
        raise Unsatisfied("assume(False)")
    return True


def settings(**kw):
    """Decorator form only (the suite uses ``@settings(max_examples=, deadline=)``)."""

    def deco(fn):
        fn._fallback_settings = kw
        return fn

    return deco


def given(*given_args, **given_kwargs):
    def deco(fn):
        sig = inspect.signature(fn)
        params = [p.name for p in sig.parameters.values()]
        pos_names = [n for n in params if n not in given_kwargs][: len(given_args)]
        pairs = list(zip(pos_names, given_args)) + list(given_kwargs.items())
        bound = {n for n, _ in pairs}
        seed_base = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            cfg = (getattr(wrapper, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", None) or {})
            n_examples = int(cfg.get("max_examples", DEFAULT_MAX_EXAMPLES))
            ran = 0
            for i in range(n_examples):
                mode = "min" if i == 0 else ("max" if i == 1 else None)
                rng = random.Random(seed_base + i)
                try:
                    drawn = {name: strat.draw(rng, mode) for name, strat in pairs}
                except Unsatisfied:
                    continue
                try:
                    fn(*a, **kw, **drawn)
                    ran += 1
                except Unsatisfied:
                    continue
                except Exception:
                    print(f"\nFalsifying example ({fn.__qualname__}, "
                          f"example {i}): {drawn!r}", file=sys.stderr)
                    raise
            if ran == 0:
                raise Unsatisfied(
                    f"{fn.__qualname__}: no example satisfied assumptions")

        remaining = [p for p in sig.parameters.values() if p.name not in bound]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


class HealthCheck:
    """Placeholder for ``suppress_health_check=`` compatibility."""

    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def install() -> types.ModuleType:
    """Register this module as ``hypothesis`` (+``.strategies``) in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0-fallback"
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "none",
                 "sampled_from", "one_of", "lists", "tuples", "builds"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
    return mod
