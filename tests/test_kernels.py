"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (hypothesis + parametrized grids)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.decode_attention.ops import decode_attention, decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_attention_ref
from repro.kernels.ragged_concat.ops import ragged_concat, ragged_concat_ref
from repro.kernels.rmsnorm.ops import fused_rmsnorm, rmsnorm_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,sq,sk,hd,causal",
    [
        (2, 4, 2, 64, 64, 32, True),     # GQA causal
        (1, 8, 1, 96, 96, 64, True),     # MQA causal
        (2, 4, 4, 33, 47, 16, False),    # MHA non-causal ragged tiles
        (1, 2, 2, 128, 256, 128, False), # long kv, MXU-aligned head
        (1, 16, 2, 8, 8, 8, True),       # tiny
    ],
)
def test_flash_attention_matches_oracle(b, h, kv, sq, sk, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, sk, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    sq=st.integers(1, 70), hd=st.sampled_from([8, 16, 32]),
    g=st.sampled_from([1, 2, 4]), kv=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq, hd, g, kv, causal):
    h = kv * g
    ks = jax.random.split(jax.random.PRNGKey(sq * hd + g), 3)
    q = jax.random.normal(ks[0], (1, h, sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, kv, sq, hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, kv, sq, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,hd",
    [(3, 8, 2, 512, 64), (1, 4, 4, 128, 32), (2, 8, 1, 1024, 128)],
)
def test_decode_attention_matches_oracle(b, h, kv, s, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    vc = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    lens = jnp.linspace(1, s, b).astype(jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_s=128)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(lens=st.lists(st.integers(1, 200), min_size=1, max_size=4))
def test_decode_attention_ragged_lengths(lens):
    b = len(lens)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, 4, 16), jnp.float32)
    kc = jax.random.normal(ks[1], (b, 2, 256, 16), jnp.float32)
    vc = jax.random.normal(ks[2], (b, 2, 256, 16), jnp.float32)
    la = jnp.array(lens, jnp.int32)
    out = decode_attention(q, kc, vc, la, block_s=64)
    ref = decode_attention_ref(q, kc, vc, la)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# ragged concat
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    lens=st.lists(st.integers(0, 16), min_size=1, max_size=6),
    c=st.sampled_from([1, 4, 8]),
)
def test_ragged_concat_matches_oracle(lens, c):
    n = len(lens)
    src = jax.random.normal(jax.random.PRNGKey(n * c), (n, 16, c), jnp.float32)
    la = jnp.array(lens, jnp.int32)
    cap = int(sum(lens)) + 8
    out, offs, total = ragged_concat(src, la, capacity=cap)
    ref_out, ref_offs, ref_total = ragged_concat_ref(src, la, cap)
    assert int(total) == int(ref_total) == sum(lens)
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(ref_offs))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out))


def test_ragged_concat_dtype_sweep():
    for dtype in (jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint8):
        src = (jnp.arange(2 * 8 * 4).reshape(2, 8, 4) % 127).astype(dtype)
        la = jnp.array([3, 8], jnp.int32)
        out, _, _ = ragged_concat(src, la, capacity=11)
        ref, _, _ = ragged_concat_ref(src, la, 11)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# fused rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 37, 64), (1, 256, 128), (5, 3, 32)])
def test_rmsnorm_matches_oracle(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], shape, dtype)
    r = jax.random.normal(ks[1], shape, dtype)
    sc = jax.random.normal(ks[2], (shape[-1],), jnp.float32)
    y, h = fused_rmsnorm(x, r, sc, block_rows=16)
    yr, hr = rmsnorm_ref(x, r, sc)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(h, np.float32), np.asarray(hr, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ---------------------------------------------------------------------------
# kernels vs the model layer (the math the system actually uses)
# ---------------------------------------------------------------------------


def test_flash_kernel_matches_model_attention():
    """The Pallas kernel and models.attention implement the same math."""
    from repro.models.attention import attention

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kv, hd = 2, 64, 8, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    model_out = attention(q, k, v, causal=True, chunk=16)          # (B,S,H,hd)
    kern_out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(kern_out.transpose(0, 2, 1, 3)),
                               np.asarray(model_out), atol=3e-5, rtol=3e-5)
