"""Registry transactionality: the kernel-module-analogue guarantees."""

import os
import stat

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AgnocastQueueFull, Registry
from repro.core.registry import (
    _J_CLEAN,
    _J_PENDING,
    ST_FREE,
    ST_USED,
    domain_lock_path,
    fifo_dir,
    sub_fifo_path,
    topic_lock_path,
)


@pytest.fixture()
def reg():
    r = Registry.create()
    yield r
    r.close()
    r.unlink()


def test_topic_index_idempotent(reg):
    t1 = reg.topic_index("a")
    t2 = reg.topic_index("b")
    assert t1 != t2
    assert reg.topic_index("a") == t1


def test_publish_take_release_lifecycle(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, freeable = reg.publish(t, p, 100, 10)
    assert seq == 1 and freeable == []
    got = reg.take(t, s)
    assert len(got) == 1 and got[0].seq == 1 and got[0].desc_off == 100
    assert reg.take(t, s) == []  # delivered exactly once
    assert reg.reclaimable(t, p) == []  # still held
    reg.release(t, p, s, seq)
    assert reg.reclaimable(t, p) == [1]  # both counters zero -> owner may free


def test_late_subscriber_does_not_receive_old(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    reg.publish(t, p, 1, 1)
    s = reg.add_subscriber(t, os.getpid())
    assert reg.take(t, s) == []  # unreceived mask snapshot at publish


def test_qos_keep_last_drops_unreceived(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)   # seq 1
    reg.publish(t, p, 2, 1)   # seq 2
    _, freeable = reg.publish(t, p, 3, 1)  # seq 3 evicts unreceived seq 1
    assert 1 in freeable
    got = reg.take(t, s)
    assert [e.seq for e in got] == [2, 3]
    assert reg.stats(t)["drops"][p] == 1


def test_queue_full_when_all_held(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)
    reg.publish(t, p, 2, 1)
    reg.take(t, s)  # subscriber now holds every ring slot
    with pytest.raises(AgnocastQueueFull):
        reg.publish(t, p, 3, 1)


def test_exclude_sub_skips_origin(reg):
    # the bridge publishes with exclude_sub=its own slot (loop prevention)
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s_bridge = reg.add_subscriber(t, os.getpid())
    s_app = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1, exclude_sub=s_bridge)
    assert reg.take(t, s_bridge) == []
    assert len(reg.take(t, s_app)) == 1


def test_journal_rollback_restores_before_image(reg):
    """Simulate a participant dying mid-mutation: PENDING journal from a
    dead pid must be rolled back by the next lock acquirer (§IV-B)."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 123, 9)
    entry_before = reg.entries[t, p, 1 % 4].copy()
    # forge a dead writer's in-flight mutation
    j = reg._journal[0]
    j["pid"] = 2**22 + 12345  # certainly-dead pid
    j["tidx"], j["pidx"], j["slot"] = t, p, 1 % 4
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = entry_before.tobytes()
    j["state"] = _J_PENDING
    reg.entries[t, p, 1 % 4]["desc_off"] = 999  # the torn write
    reg.topic_index("x")  # any op triggers recovery
    assert int(reg.entries[t, p, 1 % 4]["desc_off"]) == 123  # rolled back


def test_sweep_releases_dead_subscriber_refs(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    dead_pid = 2**22 + 54321
    with reg._lock:
        with reg._Txn(reg, t, topic=True):
            reg.topics[t]["sub_pids"][0] = dead_pid
            reg.topics[t]["sub_alive"] = np.uint64(1)
    reg.publish(t, p, 1, 1)
    assert reg.reclaimable(t, p) == []  # unreceived by "dead" sub
    rep = reg.sweep()
    assert rep["dead_subs"] == 1
    assert reg.reclaimable(t, p) == [1]


def test_sweep_marks_dead_publisher(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, 2**22 + 999, "ghost-arena", depth=4)
    rep = reg.sweep()
    assert rep["dead_pubs"] == 1
    assert "ghost-arena" in rep["orphan_arenas"]
    assert not reg.topics[t]["pub_alive"][p]


def test_attach_rejects_non_registry():
    r = Registry.create()
    try:
        import multiprocessing.shared_memory as sm

        seg = sm.SharedMemory(create=True, size=1 << 20)
        try:
            with pytest.raises(Exception):
                Registry.attach(seg.name)
        finally:
            seg.close()
            seg.unlink()
    finally:
        r.close()
        r.unlink()


# ---------------------------------------------------------------------------
# sharded metadata plane: per-topic locks + per-topic journal slots
# ---------------------------------------------------------------------------

_DEAD_PID = 2**22 + 31337  # beyond pid_max defaults: certainly not alive


def _forge_dead_writer(reg, tidx, pidx, slot):
    """Leave topic ``tidx`` looking like a writer died mid-mutation: a
    PENDING journal slot holding the before-image, plus the torn write."""
    before = reg.entries[tidx, pidx, slot].copy()
    j = reg._journal[tidx]
    j["pid"] = _DEAD_PID
    j["tidx"], j["pidx"], j["slot"] = tidx, pidx, slot
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = before.tobytes()
    j["state"] = _J_PENDING
    reg.entries[tidx, pidx, slot]["desc_off"] = 424242  # the torn write
    return before


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("pub"), st.integers(1, 512)),
            st.tuples(st.just("take"), st.integers(0, 4)),
            st.tuples(st.just("release"), st.integers(0, 3)),
        ),
        max_size=25,
    ),
)
def test_distinct_topic_ops_never_roll_back_other_journals(ops):
    """Journal slots are per topic: any op sequence on topic B must leave a
    dead writer's PENDING journal on topic A exactly as it found it (B's
    acquirers are not A's recovery agents) — and the next op on A itself
    must then roll A back."""
    reg = Registry.create()
    try:
        ta = reg.topic_index("a")
        tb = reg.topic_index("b")
        pa = reg.add_publisher(ta, os.getpid(), "arena-a", depth=4)
        pb = reg.add_publisher(tb, os.getpid(), "arena-b", depth=4)
        sb = reg.add_subscriber(tb, os.getpid())
        reg.publish(ta, pa, 7, 1)                       # seq 1 -> slot 1
        before = _forge_dead_writer(reg, ta, pa, 1)
        journal_img = reg._journal[ta].tobytes()

        taken = []
        for kind, arg in ops:
            if kind == "pub":
                try:
                    reg.publish(tb, pb, arg, 1)
                except AgnocastQueueFull:
                    pass
            elif kind == "take":
                taken.extend(reg.take(tb, sb, limit=arg or None))
            elif kind == "release" and taken:
                e = taken.pop(arg % len(taken))
                reg.release(tb, pb, sb, e.seq)

        # topic A's pending journal and torn row are untouched by B traffic
        assert reg._journal[ta].tobytes() == journal_img
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == 424242
        # ...until the next acquirer of A itself runs recovery
        reg.take(ta, reg.add_subscriber(ta, os.getpid()))
        assert int(reg._journal[ta]["state"]) == _J_CLEAN
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == int(before["desc_off"])
    finally:
        reg.close()
        reg.unlink()


def test_topic_index_recovers_dead_creator(reg):
    """A creator that died mid-create leaves a torn topic row + PENDING
    journal; the next topic_index (domain lock) must roll it back before
    trusting the name scan."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 55, 1)
    _forge_dead_writer(reg, t, p, 1)
    assert reg.topic_index("y") != t     # scan ran; never matched torn state
    assert int(reg._journal[t]["state"]) == _J_CLEAN   # rolled back
    assert int(reg.entries[t, p, 1]["desc_off"]) == 55


def test_lock_files_world_writable_despite_umask():
    """O_CREAT's mode is masked by umask: the chmod-after-create must leave
    both the domain and per-topic lock files attachable cross-user."""
    old = os.umask(0o077)
    try:
        reg = Registry.create()
        try:
            t = reg.topic_index("x")
            reg.add_publisher(t, os.getpid(), "a", depth=4)  # opens t-lock
            for path in (domain_lock_path(reg.name),
                         topic_lock_path(reg.name, t)):
                mode = stat.S_IMODE(os.stat(path).st_mode)
                assert mode == 0o666, f"{path}: {oct(mode)}"
        finally:
            reg.close()
            reg.unlink()
    finally:
        os.umask(old)


def test_unlink_removes_locks_and_fifo_dir(tmp_path):
    """Registry.unlink must leave nothing in /tmp: domain lock, per-topic
    locks, and the FIFO directory all go."""
    import glob

    reg = Registry.create()
    name = reg.name
    t = reg.topic_index("x")
    reg.add_publisher(t, os.getpid(), "a", depth=4)   # touches a topic lock
    os.makedirs(fifo_dir(name), exist_ok=True)
    fifo = sub_fifo_path(name, t, 0)
    os.mkfifo(fifo)
    reg.close()
    reg.unlink()
    leftovers = glob.glob(f"/tmp/.agnocast-{name}*")
    assert leftovers == [], leftovers


def test_sweep_unlinks_dead_subscriber_fifo(reg):
    """The janitor drops a dead subscriber's wakeup FIFO file along with
    its refs (no /tmp leak across runs)."""
    t = reg.topic_index("x")
    reg.add_publisher(t, os.getpid(), "a", depth=4)
    s = reg.add_subscriber(t, _DEAD_PID)   # creates the slot's FIFO file
    path = sub_fifo_path(reg.name, t, s)
    assert os.path.exists(path)
    rep = reg.sweep()
    assert rep["dead_subs"] == 1
    assert not os.path.exists(path)
