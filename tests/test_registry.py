"""Registry transactionality: the kernel-module-analogue guarantees."""

import os

import numpy as np
import pytest

from repro.core import AgnocastQueueFull, Registry
from repro.core.registry import ST_FREE, ST_USED, _J_PENDING


@pytest.fixture()
def reg():
    r = Registry.create()
    yield r
    r.close()
    r.unlink()


def test_topic_index_idempotent(reg):
    t1 = reg.topic_index("a")
    t2 = reg.topic_index("b")
    assert t1 != t2
    assert reg.topic_index("a") == t1


def test_publish_take_release_lifecycle(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, freeable = reg.publish(t, p, 100, 10)
    assert seq == 1 and freeable == []
    got = reg.take(t, s)
    assert len(got) == 1 and got[0].seq == 1 and got[0].desc_off == 100
    assert reg.take(t, s) == []  # delivered exactly once
    assert reg.reclaimable(t, p) == []  # still held
    reg.release(t, p, s, seq)
    assert reg.reclaimable(t, p) == [1]  # both counters zero -> owner may free


def test_late_subscriber_does_not_receive_old(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    reg.publish(t, p, 1, 1)
    s = reg.add_subscriber(t, os.getpid())
    assert reg.take(t, s) == []  # unreceived mask snapshot at publish


def test_qos_keep_last_drops_unreceived(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)   # seq 1
    reg.publish(t, p, 2, 1)   # seq 2
    _, freeable = reg.publish(t, p, 3, 1)  # seq 3 evicts unreceived seq 1
    assert 1 in freeable
    got = reg.take(t, s)
    assert [e.seq for e in got] == [2, 3]
    assert reg.stats(t)["drops"][p] == 1


def test_queue_full_when_all_held(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)
    reg.publish(t, p, 2, 1)
    reg.take(t, s)  # subscriber now holds every ring slot
    with pytest.raises(AgnocastQueueFull):
        reg.publish(t, p, 3, 1)


def test_exclude_sub_skips_origin(reg):
    # the bridge publishes with exclude_sub=its own slot (loop prevention)
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s_bridge = reg.add_subscriber(t, os.getpid())
    s_app = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1, exclude_sub=s_bridge)
    assert reg.take(t, s_bridge) == []
    assert len(reg.take(t, s_app)) == 1


def test_journal_rollback_restores_before_image(reg):
    """Simulate a participant dying mid-mutation: PENDING journal from a
    dead pid must be rolled back by the next lock acquirer (§IV-B)."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 123, 9)
    entry_before = reg.entries[t, p, 1 % 4].copy()
    # forge a dead writer's in-flight mutation
    j = reg._journal[0]
    j["pid"] = 2**22 + 12345  # certainly-dead pid
    j["tidx"], j["pidx"], j["slot"] = t, p, 1 % 4
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = entry_before.tobytes()
    j["state"] = _J_PENDING
    reg.entries[t, p, 1 % 4]["desc_off"] = 999  # the torn write
    reg.topic_index("x")  # any op triggers recovery
    assert int(reg.entries[t, p, 1 % 4]["desc_off"]) == 123  # rolled back


def test_sweep_releases_dead_subscriber_refs(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    dead_pid = 2**22 + 54321
    with reg._lock:
        with reg._Txn(reg, t, topic=True):
            reg.topics[t]["sub_pids"][0] = dead_pid
            reg.topics[t]["sub_alive"] = np.uint64(1)
    reg.publish(t, p, 1, 1)
    assert reg.reclaimable(t, p) == []  # unreceived by "dead" sub
    rep = reg.sweep()
    assert rep["dead_subs"] == 1
    assert reg.reclaimable(t, p) == [1]


def test_sweep_marks_dead_publisher(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, 2**22 + 999, "ghost-arena", depth=4)
    rep = reg.sweep()
    assert rep["dead_pubs"] == 1
    assert "ghost-arena" in rep["orphan_arenas"]
    assert not reg.topics[t]["pub_alive"][p]


def test_attach_rejects_non_registry():
    r = Registry.create()
    try:
        import multiprocessing.shared_memory as sm

        seg = sm.SharedMemory(create=True, size=1 << 20)
        try:
            with pytest.raises(Exception):
                Registry.attach(seg.name)
        finally:
            seg.close()
            seg.unlink()
    finally:
        r.close()
        r.unlink()
