"""Registry transactionality: the kernel-module-analogue guarantees."""

import os
import stat

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AgnocastQueueFull, Registry
from repro.core.registry import (
    _J_CLEAN,
    _J_PENDING,
    ST_FREE,
    ST_USED,
    domain_lock_path,
    fifo_dir,
    sub_fifo_path,
    topic_lock_path,
)


@pytest.fixture()
def reg():
    r = Registry.create()
    yield r
    r.close()
    r.unlink()


def test_topic_index_idempotent(reg):
    t1 = reg.topic_index("a")
    t2 = reg.topic_index("b")
    assert t1 != t2
    assert reg.topic_index("a") == t1


def test_publish_take_release_lifecycle(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, freeable = reg.publish(t, p, 100, 10)
    assert seq == 1 and freeable == []
    got = reg.take(t, s)
    assert len(got) == 1 and got[0].seq == 1 and got[0].desc_off == 100
    assert reg.take(t, s) == []  # delivered exactly once
    assert reg.reclaimable(t, p) == []  # still held
    reg.release(t, p, s, seq)
    assert reg.reclaimable(t, p) == [1]  # both counters zero -> owner may free


def test_late_subscriber_does_not_receive_old(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "arena0", depth=4)
    reg.publish(t, p, 1, 1)
    s = reg.add_subscriber(t, os.getpid())
    assert reg.take(t, s) == []  # unreceived mask snapshot at publish


def test_qos_keep_last_drops_unreceived(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)   # seq 1
    reg.publish(t, p, 2, 1)   # seq 2
    _, freeable = reg.publish(t, p, 3, 1)  # seq 3 evicts unreceived seq 1
    assert 1 in freeable
    got = reg.take(t, s)
    assert [e.seq for e in got] == [2, 3]
    assert reg.stats(t)["drops"][p] == 1


def test_queue_full_when_all_held(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1)
    reg.publish(t, p, 2, 1)
    reg.take(t, s)  # subscriber now holds every ring slot
    with pytest.raises(AgnocastQueueFull):
        reg.publish(t, p, 3, 1)


def test_exclude_sub_skips_origin(reg):
    # the bridge publishes with exclude_sub=its own slot (loop prevention)
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s_bridge = reg.add_subscriber(t, os.getpid())
    s_app = reg.add_subscriber(t, os.getpid())
    reg.publish(t, p, 1, 1, exclude_sub=s_bridge)
    assert reg.take(t, s_bridge) == []
    assert len(reg.take(t, s_app)) == 1


def test_journal_rollback_restores_before_image(reg):
    """Simulate a participant dying mid-mutation: PENDING journal from a
    dead pid must be rolled back by the next lock acquirer (§IV-B)."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 123, 9)
    entry_before = reg.entries[t, p, 1 % 4].copy()
    # forge a dead writer's in-flight mutation
    j = reg._journal[0]
    j["pid"] = 2**22 + 12345  # certainly-dead pid
    j["tidx"], j["pidx"], j["slot"] = t, p, 1 % 4
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = entry_before.tobytes()
    j["state"] = _J_PENDING
    reg.entries[t, p, 1 % 4]["desc_off"] = 999  # the torn write
    # any LOCKED op triggers recovery (a v4 topic_index hit is lock-free
    # and deliberately does not recover — it never trusts torn rows)
    reg.add_subscriber(t, os.getpid())
    assert int(reg.entries[t, p, 1 % 4]["desc_off"]) == 123  # rolled back


def test_sweep_releases_dead_subscriber_refs(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    dead_pid = 2**22 + 54321
    with reg._lock:
        with reg._Txn(reg, t, topic=True):
            reg.topics[t]["sub_pids"][0] = dead_pid
            reg.topics[t]["sub_alive"] = np.uint64(1)
    reg.publish(t, p, 1, 1)
    assert reg.reclaimable(t, p) == []  # unreceived by "dead" sub
    rep = reg.sweep()
    assert rep["dead_subs"] == 1
    assert reg.reclaimable(t, p) == [1]


def test_sweep_marks_dead_publisher(reg):
    t = reg.topic_index("x")
    p = reg.add_publisher(t, 2**22 + 999, "ghost-arena", depth=4)
    rep = reg.sweep()
    assert rep["dead_pubs"] == 1
    assert "ghost-arena" in rep["orphan_arenas"]
    assert not reg.topics[t]["pub_alive"][p]


def test_attach_rejects_non_registry():
    r = Registry.create()
    try:
        import multiprocessing.shared_memory as sm

        seg = sm.SharedMemory(create=True, size=1 << 20)
        try:
            with pytest.raises(Exception):
                Registry.attach(seg.name)
        finally:
            seg.close()
            seg.unlink()
    finally:
        r.close()
        r.unlink()


# ---------------------------------------------------------------------------
# sharded metadata plane: per-topic locks + per-topic journal slots
# ---------------------------------------------------------------------------

_DEAD_PID = 2**22 + 31337  # beyond pid_max defaults: certainly not alive


def _forge_dead_writer(reg, tidx, pidx, slot):
    """Leave topic ``tidx`` looking like a writer died mid-mutation: a
    PENDING journal slot holding the before-image, plus the torn write."""
    before = reg.entries[tidx, pidx, slot].copy()
    j = reg._journal[tidx]
    j["pid"] = _DEAD_PID
    j["tidx"], j["pidx"], j["slot"] = tidx, pidx, slot
    j["has_topic"], j["has_entry"] = 0, 1
    j["entry_img"] = before.tobytes()
    j["state"] = _J_PENDING
    reg.entries[tidx, pidx, slot]["desc_off"] = 424242  # the torn write
    return before


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("pub"), st.integers(1, 512)),
            st.tuples(st.just("take"), st.integers(0, 4)),
            st.tuples(st.just("release"), st.integers(0, 3)),
        ),
        max_size=25,
    ),
)
def test_distinct_topic_ops_never_roll_back_other_journals(ops):
    """Journal slots are per topic: any op sequence on topic B must leave a
    dead writer's PENDING journal on topic A exactly as it found it (B's
    acquirers are not A's recovery agents) — and the next op on A itself
    must then roll A back."""
    reg = Registry.create()
    try:
        ta = reg.topic_index("a")
        tb = reg.topic_index("b")
        pa = reg.add_publisher(ta, os.getpid(), "arena-a", depth=4)
        pb = reg.add_publisher(tb, os.getpid(), "arena-b", depth=4)
        sb = reg.add_subscriber(tb, os.getpid())
        reg.publish(ta, pa, 7, 1)                       # seq 1 -> slot 1
        before = _forge_dead_writer(reg, ta, pa, 1)
        journal_img = reg._journal[ta].tobytes()

        taken = []
        for kind, arg in ops:
            if kind == "pub":
                try:
                    reg.publish(tb, pb, arg, 1)
                except AgnocastQueueFull:
                    pass
            elif kind == "take":
                taken.extend(reg.take(tb, sb, limit=arg or None))
            elif kind == "release" and taken:
                e = taken.pop(arg % len(taken))
                reg.release(tb, pb, sb, e.seq)

        # topic A's pending journal and torn row are untouched by B traffic
        assert reg._journal[ta].tobytes() == journal_img
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == 424242
        # ...until the next acquirer of A itself runs recovery
        reg.take(ta, reg.add_subscriber(ta, os.getpid()))
        assert int(reg._journal[ta]["state"]) == _J_CLEAN
        assert int(reg.entries[ta, pa, 1]["desc_off"]) == int(before["desc_off"])
    finally:
        reg.close()
        reg.unlink()


def test_topic_index_recovers_dead_creator(reg):
    """A creator that died mid-create leaves a torn topic row + PENDING
    journal; the next topic_index (domain lock) must roll it back before
    trusting the name scan."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 55, 1)
    _forge_dead_writer(reg, t, p, 1)
    assert reg.topic_index("y") != t     # scan ran; never matched torn state
    assert int(reg._journal[t]["state"]) == _J_CLEAN   # rolled back
    assert int(reg.entries[t, p, 1]["desc_off"]) == 55


def test_lock_files_world_writable_despite_umask():
    """O_CREAT's mode is masked by umask: the chmod-after-create must leave
    both the domain and per-topic lock files attachable cross-user."""
    old = os.umask(0o077)
    try:
        reg = Registry.create()
        try:
            t = reg.topic_index("x")
            reg.add_publisher(t, os.getpid(), "a", depth=4)  # opens t-lock
            for path in (domain_lock_path(reg.name),
                         topic_lock_path(reg.name, t)):
                mode = stat.S_IMODE(os.stat(path).st_mode)
                assert mode == 0o666, f"{path}: {oct(mode)}"
        finally:
            reg.close()
            reg.unlink()
    finally:
        os.umask(old)


def test_unlink_removes_locks_and_fifo_dir(tmp_path):
    """Registry.unlink must leave nothing in /tmp: domain lock, per-topic
    locks, and the FIFO directory all go."""
    import glob

    reg = Registry.create()
    name = reg.name
    t = reg.topic_index("x")
    reg.add_publisher(t, os.getpid(), "a", depth=4)   # touches a topic lock
    os.makedirs(fifo_dir(name), exist_ok=True)
    fifo = sub_fifo_path(name, t, 0)
    os.mkfifo(fifo)
    reg.close()
    reg.unlink()
    leftovers = glob.glob(f"/tmp/.agnocast-{name}*")
    assert leftovers == [], leftovers


def test_sweep_unlinks_dead_subscriber_fifo(reg):
    """The janitor drops a dead subscriber's wakeup FIFO file along with
    its refs (no /tmp leak across runs)."""
    t = reg.topic_index("x")
    reg.add_publisher(t, os.getpid(), "a", depth=4)
    s = reg.add_subscriber(t, _DEAD_PID)   # creates the slot's FIFO file
    path = sub_fifo_path(reg.name, t, s)
    assert os.path.exists(path)
    rep = reg.sweep()
    assert rep["dead_subs"] == 1
    assert not os.path.exists(path)


# ---------------------------------------------------------------------------
# registry layout v4: seqlock reads, waiter-free release, hash lookup,
# topic generations
# ---------------------------------------------------------------------------

import threading
import time as _time

from repro.core.registry import (
    RegistryError,
    _open_and_wake,
    fifo_dir as _fifo_dir,
    pub_fifo_path,
)


def test_topic_flock_lazy_init_single_object_under_race(reg):
    """Regression (v3 bug): two threads racing the lazy per-topic lock
    open must converge on ONE _Flock — a split would leak an fd and hand
    each thread its own (useless) thread mutex."""
    results = []
    barrier = threading.Barrier(8)

    def grab():
        barrier.wait()
        results.append(reg._topic_flock(7))

    threads = [threading.Thread(target=grab) for _ in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(results) == 8
    assert len({id(r) for r in results}) == 1


def test_topic_flock_refuses_after_close():
    """close() vs a worker thread's lazy lock open: the loser must get an
    error, never a fresh fd into a closed registry (fd leak)."""
    r = Registry.create()
    try:
        r.topic_index("x")
        r.close()
        with pytest.raises(RegistryError):
            r._topic_flock(9)
    finally:
        r.unlink()


def test_open_and_wake_retries_while_reader_mid_open(tmp_path):
    """The lost-wakeup asymmetry fix: ENXIO with a live, still-interested
    peer means *mid-open*, not *gone* — the wakeup must be retried."""
    path = str(tmp_path / "f.fifo")
    os.mkfifo(path)
    fds = []

    def late_reader():
        _time.sleep(0.02)
        fds.append(os.open(path, os.O_RDONLY | os.O_NONBLOCK))

    th = threading.Thread(target=late_reader)
    th.start()
    fd = _open_and_wake(path, still_wanted=lambda: True, retry_s=1.0)
    th.join()
    assert fd is not None
    assert os.read(fds[0], 10) == b"\x01"
    os.close(fd)
    os.close(fds[0])
    # without a predicate the no-reader path still short-circuits
    path2 = str(tmp_path / "g.fifo")
    os.mkfifo(path2)
    assert _open_and_wake(path2) is None


def test_notify_owner_rechecks_armed_waiter_before_dropping(reg):
    """Owner-side mirror of the EPIPE retry: a blocked publisher mid-open
    of its slot-freed FIFO read end must still get the wakeup byte."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    os.makedirs(_fifo_dir(reg.name), exist_ok=True)
    path = pub_fifo_path(reg.name, t, p)
    try:
        os.mkfifo(path)
    except FileExistsError:
        pass
    reg.set_pub_waiter(t, p, True)
    got = []

    def late_reader():
        _time.sleep(0.02)
        got.append(os.open(path, os.O_RDONLY | os.O_NONBLOCK))

    th = threading.Thread(target=late_reader)
    th.start()
    reg._notify_owner(t, p)  # ENXIO at first: must retry, not drop
    th.join()
    assert got
    assert os.read(got[0], 10) == b"\x01"
    os.close(got[0])


def test_fast_release_is_deferred_byte_store(reg):
    """No waiter, no pending rollback: release records intent in its own
    released byte and leaves the held fold to the next lock holder."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 100, 10)
    reg.take(t, s)
    reg.release(t, p, s, seq)
    e = reg.entries[t, p, seq % 4]
    assert int(e["released"][s]) == 1          # intent recorded...
    assert (int(e["held"]) >> s) & 1 == 1      # ...fold deferred
    assert reg.reclaimable(t, p) == [seq]      # lock holder folds
    assert int(e["held"]) == 0
    assert not e["released"].any()


def test_can_publish_counts_unfolded_release_intent(reg):
    """The waiter-side re-check reads release bytes: a fast-path release
    that raced the flag arming is still visible to can_publish."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=1)
    s = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 1, 1)
    reg.take(t, s)
    assert reg.can_publish(t, p) is False
    reg.release(t, p, s, seq)                  # fast path: byte store only
    assert int(reg.entries[t, p, 0]["released"][s]) == 1
    assert reg.can_publish(t, p) is True       # effective-held sees the byte


def test_release_with_armed_waiter_takes_locked_path_and_wakes(reg):
    """An armed waiter flag routes release onto the locked protocol: held
    cleared under the lock, no lingering byte, one FIFO wakeup."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=2)
    s = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 1, 1)
    reg.take(t, s)
    os.makedirs(_fifo_dir(reg.name), exist_ok=True)
    path = pub_fifo_path(reg.name, t, p)
    try:
        os.mkfifo(path)
    except FileExistsError:
        pass
    rfd = os.open(path, os.O_RDONLY | os.O_NONBLOCK)
    try:
        reg.set_pub_waiter(t, p, True)
        reg.release(t, p, s, seq)
        e = reg.entries[t, p, seq % 2]
        assert int(e["held"]) == 0
        assert not e["released"].any()
        assert os.read(rfd, 10) == b"\x01"
    finally:
        os.close(rfd)


def test_seqlock_fallback_repairs_crashed_writer_parity(reg):
    """A writer that died inside its critical section leaves wseq odd.
    With its PENDING journal naming a dead pid, hint readers must take the
    locked path whose recovery repairs parity + rolls back; a bare odd
    counter (died before journaling) yields a dirty-but-bounded hint and
    is repaired by the topic's next locked op."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 1, 1)
    # tier 3: wedged — PENDING journal from a dead writer
    _forge_dead_writer(reg, t, p, 1)
    reg.topics[t]["wseq"] = int(reg.topics[t]["wseq"]) + 1  # "crashed" odd
    assert reg.can_publish(t, p) is True        # locked repair, did not hang
    assert int(reg.topics[t]["wseq"]) % 2 == 0  # parity repaired
    assert int(reg._journal[t]["state"]) == _J_CLEAN
    assert int(reg.entries[t, p, 1]["desc_off"]) == 1  # torn write undone
    # tier 2: bare odd counter, clean journal — hint answers unvalidated,
    # the next locked op repairs the parity
    reg.topics[t]["wseq"] = int(reg.topics[t]["wseq"]) + 1
    assert reg.can_publish(t, p) in (True, False)   # bounded, no hang
    reg.publish(t, p, 2, 1)                         # locked op -> repair
    assert int(reg.topics[t]["wseq"]) % 2 == 0


def test_rollback_preserves_concurrent_release_intent(reg):
    """An entry before-image restore must OR-merge the current released
    bytes: a subscriber's lock-free release is never undone by somebody
    else's rollback."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 123, 9)
    reg.take(t, s)                               # held by s
    slot = seq % 4
    _forge_dead_writer(reg, t, p, slot)          # before-image: held, no byte
    reg.entries[t, p, slot]["released"][s] = 1   # concurrent fast release
    # next lock holder: rollback (restores held + desc_off), merge byte, fold
    assert reg.reclaimable(t, p) == [seq]
    assert int(reg.entries[t, p, slot]["desc_off"]) == 123
    assert not reg.entries[t, p, slot]["released"].any()


def test_rollback_keeps_wseq_monotonic(reg):
    """Restoring a topic before-image must never rewind wseq (ABA: a
    reader that snapshotted the old value would validate a torn read)."""
    t = reg.topic_index("x")
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    reg.publish(t, p, 1, 1)
    w0 = int(reg.topics[t]["wseq"])              # even
    j = reg._journal[t]
    j["pid"] = _DEAD_PID
    j["tidx"], j["pidx"], j["slot"] = t, p, -1
    j["has_topic"], j["has_entry"] = 1, 0
    j["topic_img"] = reg.topics[t].tobytes()     # image carries wseq == w0
    j["state"] = _J_PENDING
    reg.topics[t]["wseq"] = w0 + 10              # later activity (even)
    reg.add_subscriber(t, os.getpid())           # locked op -> rollback
    w1 = int(reg.topics[t]["wseq"])
    assert w1 % 2 == 0
    assert w1 > w0 + 10                          # strictly advanced, never rewound


def test_seqlock_readers_never_observe_torn_rows(reg):
    """Property the whole fast plane stands on: hammer lock-free reads
    against a writer that deliberately parks the row in an inconsistent
    intermediate state inside every critical section — a validated
    snapshot must never contain it (retry/fallback instead)."""
    t = reg.topic_index("x")
    stop = threading.Event()
    errors = []

    def writer():
        try:
            while not stop.is_set():
                with reg._locked(t):
                    row = reg.topics[t]
                    row["name"] = b"TORN"        # never a valid state:
                    row["sub_alive"] = 0xDEAD    # fields mutated separately
                    _time.sleep(0)
                    row["name"] = b"x"
                    row["sub_alive"] = 0
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    def snap():
        row = reg.topics[t]
        return bytes(row["name"]).rstrip(b"\0"), int(row["sub_alive"])

    th = threading.Thread(target=writer)
    th.start()
    try:
        validated = 0
        for _ in range(3000):
            ok, val = reg._seqlock_read(t, snap)
            if ok:
                assert val == (b"x", 0), f"torn snapshot validated: {val}"
                validated += 1
    finally:
        stop.set()
        th.join()
    assert not errors
    assert validated > 0  # the fast path actually ran


def test_topic_index_hash_scales_and_is_stable(reg):
    """O(1) lookup at v4 scale: hundreds of topics resolve, stay stable
    across handles, and an unknown name still raises."""
    names = [f"scale/topic-{i}" for i in range(300)]
    idxs = [reg.topic_index(n) for n in names]
    assert len(set(idxs)) == len(names)
    assert [reg.topic_index(n) for n in names] == idxs   # fast-path hits
    other = Registry.attach(reg.name)
    try:
        assert [other.topic_index(n, create=False) for n in names] == idxs
    finally:
        other.close()
    with pytest.raises(RegistryError):
        reg.topic_index("scale/none-such", create=False)


def test_destroy_topic_recycles_with_fresh_generation(reg):
    """destroy -> recreate bumps the row generation: stale handles are
    fenced out of the recycled slot (publish raises, take empty, release
    no-op) and the dead incarnation's FIFO files are gone."""
    t = reg.topic_index("x")
    g = reg.topic_gen(t)
    p = reg.add_publisher(t, os.getpid(), "a", depth=4)
    s = reg.add_subscriber(t, os.getpid())
    seq, _ = reg.publish(t, p, 1, 1, gen=g)
    fifo = sub_fifo_path(reg.name, t, s)
    assert os.path.exists(fifo)
    assert reg.destroy_topic("x") is True
    assert not os.path.exists(fifo)              # recycled slot: fresh inodes
    with pytest.raises(RegistryError):
        reg.topic_index("x", create=False)       # tombstoned
    t2 = reg.topic_index("x")                    # recreate (lowest free row)
    assert t2 == t
    g2 = reg.topic_gen(t2)
    assert g2 == g + 1
    # the new tenant's plane, with a stale handle poking at it
    p2 = reg.add_publisher(t2, os.getpid(), "b", depth=4)
    s2 = reg.add_subscriber(t2, os.getpid())
    seq2, _ = reg.publish(t2, p2, 7, 1, gen=g2)
    got = reg.take(t2, s2, gen=g2)
    assert [e.seq for e in got] == [seq2]
    with pytest.raises(RegistryError):
        reg.publish(t, p, 9, 1, gen=g)           # stale gen: rejected
    assert reg.take(t, s, gen=g) == []           # stale gen: nothing
    reg.release(t, p2, s2, seq2, gen=g)          # stale gen: must not touch
    assert reg.reclaimable(t2, p2) == []         # s2's ref survived intact
    reg.release(t2, p2, s2, seq2, gen=g2)
    assert reg.reclaimable(t2, p2) == [seq2]
