"""Arena allocator: unit + property tests (the shared-heap substrate)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Arena, ArenaError, OutOfArenaMemory


@pytest.fixture()
def arena():
    a = Arena.create(1 << 20)
    yield a
    a.close()
    a.unlink()


def test_alloc_free_roundtrip(arena):
    off = arena.alloc(1000)
    assert off >= 4096 and off % 64 == 0
    assert arena.live_bytes > 0
    arena.free(off)
    assert arena.live_bytes == 0


def test_offset_zero_is_never_allocated(arena):
    # offset 0 is the NULL analogue: the header region is reserved
    offs = [arena.alloc(64) for _ in range(100)]
    assert all(o >= 4096 for o in offs)


def test_oom_raises(arena):
    with pytest.raises(OutOfArenaMemory):
        arena.alloc(2 << 20)


def test_only_owner_allocates(arena):
    other = Arena.attach(arena.name)
    try:
        with pytest.raises(ArenaError):
            other.alloc(64)
        with pytest.raises(ArenaError):
            other.free(4096)
    finally:
        other.close()


def test_views_are_shared_and_readonly_for_attachers(arena):
    off = arena.alloc(256)
    w = arena.view(off, 256)
    w[:] = np.arange(256, dtype=np.uint8)
    other = Arena.attach(arena.name)
    try:
        r = other.view(off, 256)
        assert np.array_equal(r, np.arange(256, dtype=np.uint8))
        assert not r.flags.writeable  # MMU read-only analogue
        with pytest.raises(ValueError):
            r[0] = 1
    finally:
        other.close()


def test_realloc_grow_preserves_data(arena):
    off = arena.alloc(128)
    arena.view(off, 128)[:] = 7
    off2 = arena.realloc(off, 4096)
    assert np.all(arena.view(off2, 128) == 7)


def test_realloc_in_place_when_adjacent_free(arena):
    off = arena.alloc(128)
    off2 = arena.realloc(off, 1024)
    assert off2 == off  # nothing after it: grows in place


def test_coalescing_allows_big_alloc_after_frees(arena):
    offs = [arena.alloc(300_000) for _ in range(3)]
    with pytest.raises(OutOfArenaMemory):
        arena.alloc(500_000)
    for o in offs:
        arena.free(o)
    arena.alloc(1_000_000)  # coalesced: whole arena usable again


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.tuples(st.just("alloc"), st.integers(1, 4096)),
            st.tuples(st.just("free"), st.integers(0, 30)),
            st.tuples(st.just("realloc"), st.integers(1, 8192)),
        ),
        max_size=60,
    )
)
def test_property_no_overlap_and_conservation(ops):
    """System invariant: live blocks never overlap, never exceed capacity,
    and block contents survive arbitrary alloc/free/realloc interleavings."""
    a = Arena.create(1 << 20)
    try:
        live: list[tuple[int, int, int]] = []  # (off, nbytes, fill)
        fill = 0
        for kind, arg in ops:
            try:
                if kind == "alloc":
                    fill += 1
                    off = a.alloc(arg)
                    a.view(off, arg, writeable=True)[:] = fill % 251
                    live.append((off, arg, fill % 251))
                elif kind == "free" and live:
                    off, _, _ = live.pop(arg % len(live))
                    a.free(off)
                elif kind == "realloc" and live:
                    i = arg % len(live)
                    off, n, f = live[i]
                    new_off = a.realloc(off, arg)
                    live[i] = (new_off, min(n, arg), f)
            except OutOfArenaMemory:
                pass
            # invariant: pairwise disjoint [off, off+n)
            spans = sorted((off, off + a._live[off]) for off, _, _ in live)
            for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
                assert e1 <= s2, "overlapping allocations"
            # invariant: content preserved
            for off, n, f in live:
                assert np.all(a.view(off, n) == f), "clobbered block"
    finally:
        a.close()
        a.unlink()
