"""Observability plane (repro.obs): shm trace rings, cross-process flow
reconstruction, the unified metrics registry + exporter, the agno_top
snapshot CLI — and the churn contract: SIGKILL a replica mid-flow and the
superseded attempt's flow must read as *truncated* (no phantom terminal
record from its late chunks) while the replayed attempt, under a fresh
trace id, is the rid's exactly-one *complete* flow."""

import gc
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import POINT_CLOUD2, Domain, EventExecutor
from repro.obs import flows as F
from repro.obs import metrics as M
from repro.obs import trace as T
from repro.serving import (
    FleetController,
    ReplicaPool,
    ResultsCollector,
    ShardRouter,
)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def dom():
    d = Domain.create(arena_capacity=32 << 20)
    yield d
    d.close()


def _drop_tracer(name):
    """Detach + unlink everything a test's tracing left behind (the cached
    writer ring must close before purge unlinks the segment)."""
    tr = T._tracers.pop(name, None)
    if tr is not None:
        tr.close()
    T.purge(name)


# ---------------------------------------------------------------------------
# trace ring: roundtrip, wrap, gating
# ---------------------------------------------------------------------------


def test_ring_roundtrip_records():
    name = f"obs-ring-{os.getpid()}"
    ring = T.TraceRing(name, cap=64)
    try:
        for i in range(10):
            ring.emit(i + 1, i, T.Stage.PUBLISH, arg=i * 3, flags=i & 1)
        rd = T.TraceReader(ring.name)
        recs = rd.records()
        rd.close()
        assert len(recs) == 10
        for i, (tid, t_ns, hop, stage, flags, arg, pid) in enumerate(recs):
            assert tid == i + 1 and hop == i
            assert stage == T.Stage.PUBLISH
            assert arg == i * 3 and flags == (i & 1)
            assert pid == os.getpid()
        ts = [r[1] for r in recs]
        assert ts == sorted(ts)
    finally:
        ring.close(unlink=True)


def test_ring_wrap_keeps_newest():
    name = f"obs-wrap-{os.getpid()}"
    ring = T.TraceRing(name, cap=64)
    try:
        for i in range(1, 201):
            ring.emit(i, 0, T.Stage.TAKE, arg=i)
        rd = T.TraceReader(ring.name)
        recs = rd.records()
        rd.close()
        # overwritten history is gone; the newest cap records survive, in
        # emit order
        assert [r[0] for r in recs] == list(range(137, 201))
    finally:
        ring.close(unlink=True)


def test_tracing_disabled_by_default(monkeypatch):
    monkeypatch.delenv("AGNOCAST_TRACE", raising=False)
    name = f"obs-off-{os.getpid()}"
    assert not T.enabled()
    assert T.tracer_for(name) is None       # no ring segment is created
    assert T.ring_names(name) == []


def test_tracer_for_is_per_process_singleton(monkeypatch):
    monkeypatch.setenv("AGNOCAST_TRACE", "1")
    name = f"obs-single-{os.getpid()}"
    try:
        tr = T.tracer_for(name)
        assert tr is not None and T.tracer_for(name) is tr
        tr.emit(T.next_trace_id(), 0, T.Stage.PUBLISH)
        assert len(T.ring_names(name)) == 1  # one writer ring per process
    finally:
        _drop_tracer(name)


def test_trace_ids_unique_nonzero_pid_salted():
    ids = {T.next_trace_id() for _ in range(1000)}
    assert len(ids) == 1000 and 0 not in ids
    assert all(i >> 40 == (os.getpid() & 0x3F_FFFF) for i in ids)


# ---------------------------------------------------------------------------
# unified metrics: registry, weakref lifetime, export, shims
# ---------------------------------------------------------------------------


def test_metrics_counter_gauge_snapshot():
    reg = M.MetricsRegistry()
    c = reg.counter("bus.dropped", topic="cam")
    assert c.name == "bus.dropped{topic=cam}"
    c.inc()
    c.inc(2)
    assert c.value == 3 and int(c) == 3
    g = reg.gauge("bus.depth")
    g.set(5)
    gf = reg.gauge("bus.load", fn=lambda: 7)
    snap = reg.snapshot()
    assert snap["bus.dropped{topic=cam}"] == 3
    assert snap["bus.depth"] == 5 and snap["bus.load"] == 7
    # same-named sibling (two bridges on one topic) dedups, not clobbers
    c2 = reg.counter("bus.dropped", topic="cam")
    c2.inc(9)
    snap = reg.snapshot()
    assert snap["bus.dropped{topic=cam}"] == 3
    assert snap["bus.dropped{topic=cam}#2"] == 9
    assert gf.value == 7


def test_metrics_weakref_dies_with_owner():
    reg = M.MetricsRegistry()
    c = reg.counter("tmp.leaky")
    c.inc()
    assert "tmp.leaky" in reg.snapshot()
    del c
    gc.collect()
    # a dead bridge's counts must not haunt later snapshots
    assert "tmp.leaky" not in reg.snapshot()


def test_metrics_export_roundtrip():
    reg = M.MetricsRegistry()
    c = reg.counter("x.drops")
    c.inc(5)
    domain = f"obs-mx-{os.getpid()}"
    exp = M.MetricsExporter(domain, reg=reg)
    try:
        exp.publish()
        snaps = M.read_exports(domain)
        assert snaps[os.getpid()]["x.drops"] == 5
    finally:
        exp.close(unlink=True)


def test_migrated_counter_shims_still_read(dom):
    """The scattered per-object counters moved into repro.obs.metrics;
    the old attribute names stay readable (back-compat shims)."""
    router = ShardRouter(dom, range(2))
    assert router.shed == 0 and router.shed_bytes == 0
    router._shed.inc(2)
    router._shed_bytes.inc(100)
    assert (router.shed, router.shed_bytes) == (2, 100)
    coll = ResultsCollector(dom, shards=range(1))
    assert coll.superseded == 0 and coll.dropped_window == 0
    coll._superseded.inc()
    assert coll.superseded == 1 and coll.stats()["superseded"] == 1
    router.close()
    coll.close()


# ---------------------------------------------------------------------------
# flow reconstruction: synthetic rings, then a live traced domain
# ---------------------------------------------------------------------------


def test_synthetic_flow_reconstruction():
    name = f"obs-synth-{os.getpid()}"
    ring = T.TraceRing(name, cap=256)
    try:
        msg, cut, srv = 1001, 1002, 1003
        for st in (T.Stage.PUBLISH, T.Stage.NOTIFY, T.Stage.TAKE,
                   T.Stage.CB_START, T.Stage.CB_END, T.Stage.RELEASE):
            ring.emit(msg, 0, st)
        ring.emit(cut, 0, T.Stage.PUBLISH)       # truncated: no release
        ring.emit(cut, 0, T.Stage.NOTIFY)
        ring.emit(srv, 0, T.Stage.SERVE_ENQ, arg=7)
        ring.emit(srv, 0, T.Stage.SERVE_FLUSH, arg=7)
        ring.emit(srv, 1, T.Stage.SERVE_ENQ, arg=7)
        ring.emit(srv, 2, T.Stage.SERVE_REASM, arg=0)
        ring.emit(srv, 2, T.Stage.SERVE_REASM, arg=1, flags=T.FLAG_EOS)

        agg = F.FlowAggregator(name)
        by_tid = {f.trace_id: f for f in agg.collect()}
        agg.close()
        assert set(by_tid) == {msg, cut, srv}

        f = by_tid[msg]
        assert f.complete and not f.serving and f.monotonic()
        bd = f.breakdown()
        stages = [v for k, v in bd.items() if k != "e2e"]
        assert all(v >= 0 for v in stages)
        # the per-stage deltas telescope exactly to the e2e delta
        assert abs(sum(stages) - bd["e2e"]) < 1e-12

        assert by_tid[cut].truncated
        f = by_tid[srv]
        assert f.serving and f.complete
        bd = f.breakdown()
        for k in ("enqueue_to_flush", "flush_to_replica",
                  "replica_to_first_chunk", "stream", "e2e"):
            assert bd[k] >= 0, (k, bd)
    finally:
        ring.close(unlink=True)


def test_traced_pubsub_message_flows(monkeypatch):
    """Live single-domain loop with tracing on: every published message's
    flow is recovered complete, with non-negative stage deltas."""
    monkeypatch.setenv("AGNOCAST_TRACE", "1")
    dom = Domain.create(arena_capacity=4 << 20)
    N = 6
    try:
        pub = dom.create_publisher(POINT_CLOUD2, "obs/t", depth=8)
        sub = dom.create_subscription(POINT_CLOUD2, "obs/t")
        for i in range(N):
            m = pub.borrow_loaded_message()
            m.data.extend(np.full(64, i, np.uint8))
            pub.publish(m)
            for ptr in sub.take():
                ptr.release()
        agg = F.FlowAggregator(dom.name)
        done = [f for f in agg.message_flows() if f.complete]
        stats = agg.breakdown_stats(done)
        agg.close()
        assert len(done) == N
        for f in done:
            assert f.monotonic()
            bd = f.breakdown()
            assert bd["e2e"] >= 0
            assert all(v >= 0 for k, v in bd.items())
        assert stats["publish_to_wakeup"]["n"] == N
        assert stats["e2e"]["p50"] >= 0
    finally:
        name = dom.name
        dom.close()
        _drop_tracer(name)


# ---------------------------------------------------------------------------
# the churn contract: SIGKILL mid-flow -> truncated old attempt, fresh
# complete flow via replay; respawn -> new incarnation's records show up
# ---------------------------------------------------------------------------


def test_flow_reconstruction_under_churn(monkeypatch):
    monkeypatch.setenv("AGNOCAST_TRACE", "1")  # spawned replicas inherit it
    dom = Domain.create(arena_capacity=32 << 20)
    K, N, POST, MAX_NEW = 2, 16, 8, 4
    pool = ReplicaPool(dom, range(K), model="echo", slots=2,
                       round_period_s=0.005)
    try:
        pool.wait_ready(60)
        router = ShardRouter(dom, range(K), max_new=MAX_NEW)
        completions: dict[int, int] = {}

        def on_complete(rid, toks):
            completions[rid] = completions.get(rid, 0) + 1
            router.complete(rid)

        collector = ResultsCollector(dom, shards=range(K),
                                     on_complete=on_complete,
                                     on_progress=router.touch)
        controller = FleetController(pool, router, collector,
                                     autoscale=False, respawn=True,
                                     respawn_backoff_s=0.0,
                                     stall_replay_s=5.0, flush_timeout_s=5.0)
        ex = EventExecutor(name="obs-churn-head")
        collector.attach_executor(ex)
        controller.attach_executor(ex, period_s=0.05)
        rng = np.random.default_rng(42)
        rids = [router.submit(rng.integers(0, 999, 8)) for _ in range(N)]
        router.flush()
        ex.spin(until=lambda: collector.n_completed >= N // 4, timeout=30)

        # kill the busiest shard mid-flow: its trace ring survives in shm
        # (writers never unlink) as the truncated-flow evidence
        per_shard: dict[int, int] = {}
        for rec in router.inflight.values():
            per_shard[rec.shard] = per_shard.get(rec.shard, 0) + 1
        victim = max(per_shard, key=per_shard.get)
        dead_pid = pool._procs[victim].pid
        pool.kill(victim)
        ex.spin(until=lambda: collector.n_completed >= N, timeout=120)
        ex.spin(until=lambda: (controller.respawns >= 1
                               and victim in router.ring), timeout=60)

        # post-respawn traffic: the fresh incarnation serves new flows
        post = [router.submit(rng.integers(0, 999, 8)) for _ in range(POST)]
        shards_post = {rid: router.inflight[rid].shard for rid in post}
        router.flush()
        ex.spin(until=lambda: collector.n_completed >= N + POST, timeout=60)
        ex.shutdown()

        assert completions == {r: 1 for r in rids + post}
        assert router.replays >= 1
        assert any(s == victim for s in shards_post.values())

        # reconstruction off the rings — including the dead incarnation's
        # ring — must return promptly (readers never block on a writer)
        agg = F.FlowAggregator(dom.name)
        sflows = agg.serving_flows()
        agg.close()
        by_rid: dict[int, list] = {}
        for f in sflows:
            enq = f.first(T.Stage.SERVE_ENQ, 0)
            if enq is not None:
                by_rid.setdefault(enq[5], []).append(f)

        for rid in rids + post:
            fs = by_rid.get(rid & 0xFFFF_FFFF)
            assert fs, f"rid {rid}: no flow recovered"
            comp = [f for f in fs if f.complete]
            # exactly ONE complete flow per rid: replay mints a fresh
            # trace id, and the dead generation's late chunks must not
            # stamp a phantom terminal record on the superseded attempt
            assert len(comp) == 1, rid
            assert comp[0].monotonic()
            assert all(v >= 0 for v in comp[0].breakdown().values())
        truncated = [f for fs in by_rid.values() for f in fs if f.truncated]
        assert len(truncated) >= 1          # the kill bit someone mid-flow

        # the respawned incarnation (a NEW pid) carried the post-kill
        # victim-shard flows end to end
        for rid in post:
            if shards_post[rid] != victim:
                continue
            (f,) = [f for f in by_rid[rid & 0xFFFF_FFFF] if f.complete]
            renq = f.first(T.Stage.SERVE_ENQ, 1)
            assert renq is not None and renq[6] != dead_pid
        router.close()
        collector.close()
    finally:
        pool.stop()
        name = dom.name
        dom.close()
        _drop_tracer(name)


# ---------------------------------------------------------------------------
# agno_top: one-shot snapshot CLI over a live domain
# ---------------------------------------------------------------------------


def test_agno_top_once_snapshot(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "obs/topic", depth=4)
    m = pub.borrow_loaded_message()
    m.data.extend(np.ones(10, np.uint8))
    pub.publish(m)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(_REPO, "scripts", "agno_top.py"),
         dom.name, "--once"],
        capture_output=True, text=True, timeout=60, env=env, cwd=_REPO)
    assert out.returncode == 0, out.stderr
    assert "obs/topic" in out.stdout
