"""Runtime: fault tolerance, straggler policy, remesh planning, server."""

import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch.train import model_100m
from repro.models import Model
from repro.runtime import (
    FailureDetector,
    InferenceServer,
    Request,
    StragglerMonitor,
    Trainer,
    TrainerConfig,
    plan_remesh,
)


# -- failure detector ---------------------------------------------------------


def test_failure_detector_transitions():
    fd = FailureDetector([0, 1, 2], suspect_after=0.1, dead_after=0.3)
    t0 = time.monotonic()
    fd.beat(0, t0)
    fd.beat(1, t0 - 0.2)   # suspect
    fd.beat(2, t0 - 1.0)   # dead
    s = fd.state(t0)
    assert s == {0: "alive", 1: "suspect", 2: "dead"}
    assert fd.healthy(t0) == [0, 1]


# -- straggler monitor ----------------------------------------------------------


def test_straggler_flags_slow_host():
    sm = StragglerMonitor(list(range(4)), threshold=1.5, grace_steps=3)
    for step in range(6):
        for h in range(4):
            sm.record(h, 1.0 if h != 2 else 2.5)
    assert sm.stragglers() == [2]


def test_straggler_grace_period():
    sm = StragglerMonitor([0, 1], grace_steps=5)
    sm.record(0, 1.0)
    sm.record(1, 9.0)
    assert sm.stragglers() == []  # not enough evidence yet


# -- remesh planning --------------------------------------------------------------


def test_plan_remesh_keeps_model_axis():
    # 2x16x16 = 512 chips on 128 hosts (4 chips/host); lose 3 hosts
    healthy = list(range(125))
    plan = plan_remesh(healthy, 4, (2, 16, 16))
    assert plan.mesh_axes[-1] == "model"
    assert plan.mesh_shape[-1] == 16            # TP preserved
    used = np.prod(plan.mesh_shape)
    assert used <= 125 * 4
    assert plan.batch_scale <= 1.0


@settings(max_examples=30, deadline=None)
@given(st.integers(17, 300), st.sampled_from([1, 2, 4, 8]))
def test_plan_remesh_properties(n_hosts, chips):
    plan = plan_remesh(list(range(n_hosts)), chips, (2, 16, 16))
    used = int(np.prod(plan.mesh_shape))
    assert used <= n_hosts * chips              # never oversubscribe
    assert plan.mesh_shape[-1] == 16            # model extent invariant
    assert set(plan.hosts).isdisjoint(plan.dropped)
    # mesh axes match shape length
    assert len(plan.mesh_axes) == len(plan.mesh_shape)


def test_plan_remesh_too_small_raises():
    with pytest.raises(ValueError):
        plan_remesh([0], 4, (2, 16, 16))        # 4 chips < model=16


# -- trainer restart ---------------------------------------------------------------


@pytest.mark.slow
def test_trainer_checkpoint_restart(tmp_path):
    cfg = model_100m("qwen2-1.5b").scaled(num_layers=2, d_model=64, d_ff=128,
                                          vocab_size=512, num_heads=2,
                                          num_kv_heads=1, head_dim=32)
    tc = TrainerConfig(batch=2, seq_len=64, total_steps=4, ckpt_every=2,
                       ckpt_dir=str(tmp_path), zero_copy_data=False,
                       log_every=100)
    t1 = Trainer(Model(cfg), tc)
    s1 = t1.run()
    t1.close()
    assert s1["steps"] == 4
    # "crash" and restart: must resume from step 4, run to 6, data cursor kept
    tc2 = TrainerConfig(batch=2, seq_len=64, total_steps=6, ckpt_every=2,
                        ckpt_dir=str(tmp_path), zero_copy_data=False,
                        log_every=100)
    t2 = Trainer(Model(cfg), tc2)
    s2 = t2.run()
    t2.close()
    assert t2.step_num == 6
    assert t2.metrics_log[0]["step"] == 5       # continued, not restarted


# -- inference server ----------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_server():
    cfg = model_100m("qwen2-1.5b").scaled(num_layers=2, d_model=64, d_ff=128,
                                          vocab_size=512, num_heads=2,
                                          num_kv_heads=1, head_dim=32)
    model = Model(cfg)
    srv = InferenceServer(model, slots=2, max_seq=128, page_tokens=32)
    srv.load(model.init(jax.random.PRNGKey(0)))
    return srv, cfg


@pytest.mark.slow
def test_server_continuous_batching(tiny_server):
    srv, cfg = tiny_server
    rng = np.random.default_rng(1)
    for i in range(5):                          # 5 requests through 2 slots
        srv.submit(Request(rid=f"r{i}",
                           tokens=rng.integers(0, 512, int(rng.integers(4, 30))),
                           max_new=6))
    results = srv.serve()
    assert len(results) == 5
    for r in results.values():
        assert len(r.tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
    st = srv.stats()
    assert st["live_publications"] == 0
    assert st["free_pages"] == srv.pool.num_pages  # two-counter rule held


@pytest.mark.slow
def test_server_cancel_janitor(tiny_server):
    srv, _ = tiny_server
    rng = np.random.default_rng(2)
    srv.submit(Request(rid="victim", tokens=rng.integers(0, 512, 8), max_new=30))
    srv.submit(Request(rid="survivor", tokens=rng.integers(0, 512, 8), max_new=4))
    srv._admit()
    srv._decode_round()
    assert srv.cancel("victim")
    results = srv.serve()
    assert "survivor" in results and "victim" not in results
    assert srv.pool.free_pages == srv.pool.num_pages
