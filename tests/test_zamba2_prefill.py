"""§Perf Z1 correctness: zamba2 parallel prefill == sequential replay."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import zamba2_model as zm


def test_parallel_prefill_matches_sequential_replay():
    cfg = get_smoke_config("zamba2-2.7b")
    params = zm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 20)), jnp.int32)

    logits_p, cache_p = zm.prefill(params, tokens, cfg, max_seq=32)
    logits_s, cache_s = zm.prefill_sequential(params, tokens, cfg, max_seq=32)

    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_s, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(
        np.asarray(cache_p["mamba"]["ssm"], np.float32),
        np.asarray(cache_s["mamba"]["ssm"], np.float32),
        rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(
        np.asarray(cache_p["mamba"]["conv"], np.float32),
        np.asarray(cache_s["mamba"]["conv"], np.float32),
        rtol=3e-2, atol=3e-2)

    # continuing decode from both caches must agree
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)), jnp.int32)
    lp, _ = zm.decode_step(params, cache_p, nxt, cfg)
    ls, _ = zm.decode_step(params, cache_s, nxt, cfg)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(ls, np.float32),
                               rtol=3e-2, atol=3e-2)
