"""Pub/sub semantics in-process: unsized growth, smart pointer, zero-copy."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    POINT_CLOUD2,
    TOKEN_BATCH,
    Domain,
    deserialize,
    serialize,
)


@pytest.fixture()
def dom():
    d = Domain.create(arena_capacity=16 << 20)
    yield d
    d.close()


def test_unsized_growth_then_publish(dom):
    """The paper's requirement #1: reallocation at arbitrary times
    (push_back) must be legal right up to publish."""
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    for i in range(1000):  # forces multiple reallocations
        m.data.push_back(i % 256)
    m.data.extend(np.arange(500) % 256)
    m.set("width", 1500)
    pub.publish(m)
    (ptr,) = sub.take()
    assert ptr.data.shape == (1500,)
    assert ptr.data[999] == 999 % 256 and ptr.data[1000] == 0
    ptr.release()


def test_zero_copy_views_alias_publisher_memory(dom):
    """True zero-copy: the subscriber's array IS the publisher's bytes."""
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    m.data.extend(np.zeros(16, np.uint8))
    data_off = m.data.offset
    pub.publish(m)
    (ptr,) = sub.take()
    base_pub = dom.arena._buf[data_off : data_off + 16]
    assert np.shares_memory(ptr.data, base_pub)
    ptr.release()


def test_publish_is_move(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    m = pub.borrow_loaded_message()
    m.data.extend(np.zeros(8, np.uint8))
    pub.publish(m)
    with pytest.raises(AttributeError):
        _ = m.data  # loan invalidated: rvalue semantics (§VII-A)


def test_smart_pointer_two_counter_rule(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    m.data.extend(np.zeros(64, np.uint8))
    pub.publish(m)
    assert pub.reclaim() == 0  # unreceived != 0
    (ptr,) = sub.take()
    assert pub.reclaim() == 0  # held != 0
    c1 = ptr.clone()
    c2 = c1.clone()
    ptr.release()
    c1.release()
    assert pub.reclaim() == 0  # c2 still holds
    c2.release()
    assert pub.reclaim() == 1  # both counters zero -> freed by owner
    assert dom.arena.live_bytes == 0


def test_gc_releases_reference(dom):
    import gc

    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    m.data.extend(np.zeros(8, np.uint8))
    pub.publish(m)
    ptrs = sub.take()
    del ptrs  # dropped without explicit release
    gc.collect()
    assert pub.reclaim() == 1


def test_use_after_release_raises(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    m.data.extend(np.zeros(8, np.uint8))
    pub.publish(m)
    (ptr,) = sub.take()
    ptr.release()
    with pytest.raises(ValueError):
        ptr.clone()


def test_two_subscribers_both_receive(dom):
    pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=4)
    s1 = dom.create_subscription(POINT_CLOUD2, "pc")
    s2 = dom.create_subscription(POINT_CLOUD2, "pc")
    m = pub.borrow_loaded_message()
    m.data.extend(np.arange(10, dtype=np.uint8))
    pub.publish(m)
    (p1,) = s1.take()
    assert pub.reclaim() == 0  # s2 has not received yet (unreceived count!)
    (p2,) = s2.take()
    assert np.array_equal(p1.data, p2.data)
    p1.release()
    p2.release()
    assert pub.reclaim() == 1


def test_token_batch_message(dom):
    pub = dom.create_publisher(TOKEN_BATCH, "batch", depth=4)
    sub = dom.create_subscription(TOKEN_BATCH, "batch")
    m = pub.borrow_loaded_message()
    m.tokens.extend(np.arange(4096, dtype=np.int32))
    m.row_lengths.extend(np.array([1024, 1024, 2048], np.int32))
    m.set("step", 17)
    pub.publish(m)
    (ptr,) = sub.take()
    assert ptr.tokens.dtype == np.int32 and ptr.tokens.shape == (4096,)
    assert int(ptr.get("step")) == 17
    assert ptr.row_lengths.sum() == 4096
    ptr.release()


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(0, 4000), min_size=1, max_size=8))
def test_property_publish_take_roundtrip(sizes):
    """Any sequence of unsized payloads round-trips bit-exactly, and the
    arena returns to empty after release+reclaim (no leaks)."""
    with Domain.create(arena_capacity=32 << 20) as dom:
        pub = dom.create_publisher(POINT_CLOUD2, "pc", depth=16)
        sub = dom.create_subscription(POINT_CLOUD2, "pc")
        payloads = []
        for i, n in enumerate(sizes):
            m = pub.borrow_loaded_message()
            data = (np.arange(n) * (i + 1) % 256).astype(np.uint8)
            m.data.extend(data)
            m.set("width", n)
            payloads.append(data)
            pub.publish(m)
        ptrs = sub.take()
        assert len(ptrs) == len(sizes)
        for ptr, want in zip(ptrs, payloads):
            assert np.array_equal(ptr.data, want)
            ptr.release()
        pub.reclaim()
        assert dom.arena.live_bytes == 0


def test_serialization_roundtrip_all_dtypes():
    m = TOKEN_BATCH.plain()
    m.tokens = np.arange(100, dtype=np.int32)
    m.row_lengths = np.array([50, 50], np.int32)
    m.stamp = 3.25
    m.epoch = 2
    m.step = 9
    out = deserialize(serialize(m))
    assert np.array_equal(out["tokens"], m.tokens)
    assert out["stamp"][0] == 3.25 and out["step"][0] == 9
