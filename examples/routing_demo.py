"""Federated routing demo: three agnocast domains, one conventional plane.

Topology (a chain — domain B relays A's traffic onward to C through its own
zero-copy plane):

    domain A ──bus ab── domain B ──bus bc── domain C

Each domain runs a :class:`Router` with a longest-prefix routing table:

* ``sensing/``       → federate over every attached bus
* ``sensing/private``→ blackhole (never leaves the local domain)

A message published once in A arrives exactly once in B and exactly once in
C (hop count 2, origin tag A), while the private topic stays in A.  All
publishes use ``publish_blocking`` — backpressure, when it occurs, waits on
the slot-freed FIFO instead of polling (the parked-bridge path itself is
exercised in ``tests/test_routing.py``).

    PYTHONPATH=src python examples/routing_demo.py
"""

import time

import numpy as np

from repro.core import POINT_CLOUD2, Bus, Domain, EventExecutor, Router

TOPIC = "sensing/points"
PRIVATE = "sensing/private/raw"

bus_ab, bus_bc = Bus().start(), Bus().start()
doms = {k: Domain.create(arena_capacity=32 << 20) for k in "ABC"}
links = {"A": [("ab", bus_ab)], "B": [("ab", bus_ab), ("bc", bus_bc)],
         "C": [("bc", bus_bc)]}

routers = {}
for k, dom in doms.items():
    r = Router(dom)
    for name, bus in links[k]:
        r.add_remote(name, bus.path)
        r.add_route("sensing/", name)
    r.add_route("sensing/private", None)   # longest prefix wins: stays local
    r.activate(POINT_CLOUD2, TOPIC)
    r.activate(POINT_CLOUD2, PRIVATE)      # no matching remote -> no bridge
    routers[k] = r

pub = doms["A"].create_publisher(POINT_CLOUD2, TOPIC, depth=4)
priv_pub = doms["A"].create_publisher(POINT_CLOUD2, PRIVATE, depth=4)
got = {k: [] for k in "BC"}

ex = EventExecutor(name="federation")
for k in "BC":
    sub = doms[k].create_subscription(POINT_CLOUD2, TOPIC)
    ex.add_subscription(sub, lambda ptr, k=k: got[k].append(
        (int(np.asarray(ptr.data)[0]), ptr.hops, ptr.src_tag)))
    psub = doms[k].create_subscription(POINT_CLOUD2, PRIVATE)
    ex.add_subscription(psub, lambda ptr, k=k: got[k].append(("LEAK", -1, -1)))
for r in routers.values():
    r.register(ex)
time.sleep(0.3)  # let the bus subscriptions land

for i in range(3):
    for p in (pub, priv_pub):
        m = p.borrow_loaded_message()
        m.data.extend(np.full(1 << 16, i, np.uint8))   # 64 KiB payload
        m.set("stamp", time.monotonic())
        p.reclaim()
        p.publish_blocking(m)                          # event-driven, no poll

ex.spin(until=lambda: all(len(v) >= 3 for v in got.values()), timeout=20)
ex.spin(timeout=0.5)  # would surface ping-pong duplicates or a private leak
ex.shutdown()

tag_a = routers["A"].tag
for k in "BC":
    vals = [v for v, _, _ in got[k]]
    hops = sorted({h for _, h, _ in got[k]})
    tags = {t for _, _, t in got[k]}
    print(f"domain {k}: payloads={vals} hops={hops} origin_ok={tags == {tag_a}}")
    assert vals == [0, 1, 2], "exactly-once delivery violated"
    assert tags == {tag_a}
assert [h for _, h, _ in got["B"]] == [1, 1, 1]   # one bus hop to B
assert [h for _, h, _ in got["C"]] == [2, 2, 2]   # relayed through B
print("private topic never left A; federation delivered exactly once. OK")

for r in routers.values():
    r.close()
for d in doms.values():
    d.close()
bus_ab.stop()
bus_bc.stop()
