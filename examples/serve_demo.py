"""Serving driver: event-driven ingest + device-arena KV hand-off.

Requests with unsized prompts are published as ``TOKEN_BATCH`` messages on
an agnocast topic; the server runs on an :class:`EventExecutor` — the
subscription callback admits requests (zero-copy read of the token field
out of the publisher's arena) and a timer drives continuous-batching
rounds. Prefill publishes each request's KV pages into the device page
pool, decode subscribes, and the two-counter rule frees pages exactly when
the last consumer lets go. A mid-flight cancellation exercises the janitor.

    PYTHONPATH=src python examples/serve_demo.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import TOKEN_BATCH, Domain, EventExecutor
from repro.launch.train import model_100m
from repro.models import Model
from repro.runtime import InferenceServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = model_100m("qwen2-1.5b").scaled(num_layers=4, d_model=256,
                                          d_ff=1024, num_heads=4,
                                          num_kv_heads=2)
    model = Model(cfg)
    server = InferenceServer(model, slots=4, max_seq=256)
    server.load(model.init(jax.random.PRNGKey(0)))

    with Domain.create(arena_capacity=8 << 20) as dom:
        pub = dom.create_publisher(TOKEN_BATCH, "serve/requests", depth=8)
        sub = dom.create_subscription(TOKEN_BATCH, "serve/requests")
        ex = EventExecutor(name="serve")
        server.attach_executor(ex, sub, max_new=args.max_new)

        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48)),
                                dtype=np.int32)
                   for _ in range(args.requests)]
        # publish in a few unsized batches (ragged rows, one publish each)
        for chunk in np.array_split(np.arange(args.requests), 3):
            m = pub.borrow_loaded_message()
            for i in chunk:
                m.tokens.extend(prompts[i])
                m.row_lengths.extend(np.array([len(prompts[i])], np.int32))
            m.set("stamp", time.monotonic())
            pub.publish(m)

        # spin until the first wave is mid-decode, then cancel one (janitor demo)
        ex.spin(until=lambda: len(server._active) > 0, timeout=60)
        if not server._active:
            raise RuntimeError("demo timed out before any request was admitted")
        victim = next(iter(server._active.values()))["req"].rid
        print(f"[serve] cancelling {victim} mid-decode "
              f"(pages before: {server.pool.free_pages} free)")
        server.cancel(victim)
        print(f"[serve] janitor reclaimed its pages "
              f"(pages after: {server.pool.free_pages} free)")

        done = args.requests - 1  # one cancelled
        ex.spin(until=lambda: len(server.results) >= done and server.idle,
                timeout=120)
        ex.shutdown()
        if len(server.results) < done or not server.idle:
            raise RuntimeError(
                f"demo timed out mid-decode: {len(server.results)}/{done} "
                f"done, {len(server._active)} active")
        pub.reclaim()

    results = list(server.results.values())
    if results:
        print(f"[serve] completed {len(results)} requests, "
              f"mean latency {1e3*np.mean([r.latency for r in results]):.1f} ms, "
              f"mean ttft {1e3*np.mean([r.ttft for r in results]):.1f} ms")
    else:
        print("[serve] completed 0 requests (all cancelled)")
    st = server.stats()
    assert st["live_publications"] == 0 and st["free_pages"] == server.pool.num_pages
    print("[serve] pool clean after serving — no leaked pages/publications")


if __name__ == "__main__":
    main()
