"""Serving driver: continuous batching + device-arena KV hand-off.

Batched requests with unsized prompts flow through the continuous-batching
server; prefill publishes each request's KV pages into the device page
pool, decode subscribes, and the two-counter rule frees pages exactly when
the last consumer lets go. A mid-flight cancellation exercises the janitor.

    PYTHONPATH=src python examples/serve_demo.py [--requests 12]
"""

import argparse

import jax
import numpy as np

from repro.launch.train import model_100m
from repro.models import Model
from repro.runtime import InferenceServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = model_100m("qwen2-1.5b").scaled(num_layers=4, d_model=256,
                                          d_ff=1024, num_heads=4,
                                          num_kv_heads=2)
    model = Model(cfg)
    server = InferenceServer(model, slots=4, max_seq=256)
    server.load(model.init(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(rid=f"req-{i}",
                              tokens=rng.integers(0, cfg.vocab_size,
                                                  int(rng.integers(4, 48))),
                              max_new=args.max_new))

    # admit the first wave, then cancel one mid-decode (janitor demo)
    server._admit()
    server._decode_round()
    victim = next(iter(server._active.values()))["req"].rid
    print(f"[serve] cancelling {victim} mid-decode "
          f"(pages before: {server.pool.free_pages} free)")
    server.cancel(victim)
    print(f"[serve] janitor reclaimed its pages "
          f"(pages after: {server.pool.free_pages} free)")

    results = server.serve()
    done = [r for r in results.values()]
    print(f"[serve] completed {len(done)} requests, "
          f"mean latency {1e3*np.mean([r.latency for r in done]):.1f} ms, "
          f"mean ttft {1e3*np.mean([r.ttft for r in done]):.1f} ms")
    st = server.stats()
    assert st["live_publications"] == 0 and st["free_pages"] == server.pool.num_pages
    print("[serve] pool clean after serving — no leaked pages/publications")


if __name__ == "__main__":
    main()
