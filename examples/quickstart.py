"""Quickstart: the paper's Fig. 2 API in 60 lines.

Creates a domain, publishes an *unsized* PointCloud2 message that grows via
push_back/extend (the thing TZC/LOT/IceOryx-static cannot do), receives it
zero-copy in the same process, and contrasts with the serialized path.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import POINT_CLOUD2, Domain, deserialize, serialize

with Domain.create(arena_capacity=64 << 20) as dom:
    pub = dom.create_publisher(POINT_CLOUD2, "mytopic", depth=4)
    sub = dom.create_subscription(POINT_CLOUD2, "mytopic")

    # -- borrow a loaned message and build it *in shared memory* --------------
    msg = pub.borrow_loaded_message()
    msg.data.extend(np.arange(1 << 20, dtype=np.uint8))   # 1 MB payload
    msg.data.push_back(42)            # unsized: grow after the fact, freely
    msg.data.extend(np.zeros(999, np.uint8))              # ...and again
    msg.set("width", len(msg.data))
    msg.set("stamp", time.monotonic())

    t0 = time.monotonic()
    pub.publish(msg)                  # move-publish: constant-size metadata op
    ptrs = sub.take()                 # zero-copy receive
    t1 = time.monotonic()

    view = ptrs[0].msg.data           # read-only view into the PUBLISHER's heap
    print(f"zero-copy : {len(view)} bytes visible in {1e6*(t1-t0):.1f} us, "
          f"first/last = {view[0]}/{view[-1]}")
    assert not view.flags.writeable   # subscribers cannot corrupt the heap
    ptrs[0].release()                 # refcount drops; owner may now reclaim
    pub.reclaim()

    # -- versus the conventional serialized path ------------------------------
    plain = POINT_CLOUD2.plain()
    plain.data = np.arange((1 << 20) + 1000, dtype=np.uint8)
    t0 = time.monotonic()
    wire = serialize(plain)           # the copy Agnocast eliminates
    fields = deserialize(wire)        # ...and the copy back
    t1 = time.monotonic()
    print(f"serialized: {len(fields['data'])} bytes round-trip in "
          f"{1e6*(t1-t0):.1f} us (copies: O(payload))")
