"""Sharded serving demo: rid-hash router -> K replicas -> reassembly.

The Fig. 13 pipeline shape applied to serving: requests are consistent-
hashed across K request shard topics, each owned by one replica process
(its own EventExecutor), and every replica streams its decode rounds'
token chunks onto one zero-copy results topic that a ResultsCollector
reassembles in order per rid.  Midway the demo SIGKILLs a replica: the
pool's PID/lease liveness detects it, the router re-hashes the dead
shard's in-flight rids onto the survivors (generation+1), and every rid
still completes exactly once.

    PYTHONPATH=src python examples/sharded_serve_demo.py [--replicas 3]
    PYTHONPATH=src python examples/sharded_serve_demo.py --model jax

``--model echo`` (default) runs jax-free token-echo replicas so the demo
starts in ~a second; ``--model jax`` runs real InferenceServer replicas
(tiny transformer, decode through the existing kernels).
"""

import argparse
import time

import numpy as np

from repro.core import Domain, EventExecutor
from repro.serving import ReplicaPool, ResultsCollector, ShardRouter

MODEL_KWARGS = dict(arch="qwen2-1.5b", num_layers=2, d_model=64, d_ff=128,
                    vocab_size=512, num_heads=2, num_kv_heads=1, head_dim=32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--model", default="echo", help="'echo' or 'jax'")
    args = ap.parse_args()

    K = args.replicas
    with Domain.create(arena_capacity=64 << 20) as dom:
        print(f"[serve] spawning {K} {args.model} replicas ...")
        pool = ReplicaPool(dom, range(K), model=args.model,
                          model_kwargs=(MODEL_KWARGS
                                        if args.model != "echo" else None),
                          slots=4, max_seq=128, round_period_s=0.004)
        pool.wait_ready(300)
        router = ShardRouter(dom, range(K), max_new=args.max_new)
        done = {}

        def on_complete(rid, tokens):
            done[rid] = tokens
            router.complete(rid)

        collector = ResultsCollector(dom, shards=pool.shards,
                                     on_complete=on_complete,
                                     on_progress=router.touch)
        ex = EventExecutor(name="head")
        collector.attach_executor(ex)

        def janitor():
            for shard in pool.poll():
                replayed = router.remove_shard(shard)
                print(f"[serve] replica {shard} died -> re-hashed "
                      f"{len(replayed)} in-flight rids to shards "
                      f"{router.ring.shards}")
            for rid in router.stalled(5.0):
                router.replay(rid)
            router.flush(timeout=10.0)

        ex.add_timer(0.1, janitor)

        rng = np.random.default_rng(0)
        t0 = time.monotonic()
        rids = [router.submit(rng.integers(0, 500, int(rng.integers(4, 24)),
                                           dtype=np.int32))
                for _ in range(args.requests)]
        by_shard: dict[int, int] = {}
        for rid in rids:
            s = router.inflight[rid].shard
            by_shard[s] = by_shard.get(s, 0) + 1
        print(f"[serve] routed {len(rids)} rids across shards: {by_shard}")
        router.flush()

        # chaos: kill the busiest replica once a third of the work is done
        ex.spin(until=lambda: len(done) >= args.requests // 3, timeout=120)
        busiest = max(by_shard, key=by_shard.get)
        print(f"[serve] SIGKILL replica {busiest} mid-run "
              f"({len(done)}/{args.requests} done)")
        pool.kill(busiest)

        ex.spin(until=lambda: len(done) >= args.requests, timeout=300)
        ex.shutdown()
        wall = time.monotonic() - t0
        missing = [r for r in rids if r not in done]
        assert not missing, f"lost rids: {missing}"
        assert not router.inflight
        print(f"[serve] all {len(done)} rids reassembled in order in "
              f"{wall:.2f}s ({args.requests * args.max_new / wall:.0f} tok/s "
              f"aggregate), {router.replays} replayed after the kill")
        print(f"[serve] collector: {collector.stats()}")
        print(f"[serve] shard snapshot: { {k: v['depth'] for k, v in collector.shard_stats().items()} }")
        pool.stop()
        router.close()
        collector.close()


if __name__ == "__main__":
    main()
