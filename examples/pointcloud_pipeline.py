"""The Autoware LiDAR-preprocessing demo (paper §V-D, Fig. 12/13).

Three LiDAR processes (4 fused preprocessing stages each) feed a separate
concatenate process. Run once with every edge on the serialized bus, once
with the bottleneck Top-LiDAR edge converted to Agnocast, and compare
response times:

    PYTHONPATH=src python examples/pointcloud_pipeline.py [--frames 40]
"""

import argparse

from repro.apps import run_chain


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    args = ap.parse_args()

    print(f"running {args.frames} frames per configuration...")
    base = run_chain(frames=args.frames, agnocast_edges=frozenset())
    agno = run_chain(frames=args.frames, agnocast_edges=frozenset({"top"}))

    print(f"\n{'':24}   mean     worst")
    print(f"all edges serialized : {base.mean*1e3:7.2f} ms {base.worst*1e3:8.2f} ms")
    print(f"top edge -> Agnocast : {agno.mean*1e3:7.2f} ms {agno.worst*1e3:8.2f} ms")
    print(f"improvement          : {100*(1-agno.mean/base.mean):+6.1f} % "
          f"{100*(1-agno.worst/base.worst):+7.1f} %")
    print("(paper Fig. 13: +16 % mean, +25 % worst-case)")


if __name__ == "__main__":
    main()
