"""End-to-end training driver: ~100M-param model, few hundred steps.

The full composition — zero-copy data plane (packer stage in a separate
process publishing TOKEN_BATCH over Agnocast), jitted donated train step,
async atomic checkpointing, straggler monitor — on CPU:

    PYTHONPATH=src python examples/train_demo.py \
        [--arch qwen2-1.5b] [--steps 300] [--kill-data-plane]

``--kill-data-plane`` murders the packer process mid-run to demonstrate the
paper's fault-isolation property: the registry janitor reclaims its refs,
the pipeline respawns it, training continues without a restart.
"""

import argparse
import threading
import time

from repro.launch.train import main as train_main, model_100m
from repro.models import Model
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--kill-data-plane", action="store_true")
    args = ap.parse_args()

    if not args.kill_data_plane:
        train_main(["--arch", args.arch, "--steps", str(args.steps),
                    "--batch", str(args.batch), "--seq", str(args.seq),
                    "--ckpt-dir", "/tmp/agnocast-train-demo"])
        return

    # fault-injection variant
    cfg = model_100m(args.arch)
    model = Model(cfg)
    tc = TrainerConfig(batch=args.batch, seq_len=args.seq,
                       total_steps=args.steps, ckpt_every=100,
                       ckpt_dir="/tmp/agnocast-train-demo-fi")
    with Trainer(model, tc) as tr:
        def killer():
            time.sleep(20)
            print("[demo] >>> killing the data-plane process <<<")
            tr._pipeline.kill_stage()
        threading.Thread(target=killer, daemon=True).start()
        summary = tr.run()
    print(f"[demo] finished {summary['steps']} steps "
          f"(data-plane respawns: {tr._pipeline.stats.respawns}); "
          f"loss {summary['loss_first']:.3f} -> {summary['loss_last']:.3f}")


if __name__ == "__main__":
    main()
